"""End-to-end driver: train a small LM with the full production stack —
sharded train step, AdamW+ZeRO, async checkpointing, restart-safe loop —
optionally with LUNA QAT (--quant luna_approx makes every projection run the
paper's integer D&C path in the forward pass).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --steps 50 --quant luna_approx
(kill it mid-run and re-run: it resumes from the last checkpoint.)
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402,F401  (initializes XLA under the forced host flags)

from repro.configs.base import ModelConfig  # noqa: E402
from repro.core.layers import QuantConfig  # noqa: E402
from repro.data.synthetic import SyntheticLM  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.train.trainer import Trainer, TrainerConfig  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--quant", default="bf16")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="demo-lm", family="dense", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=2048,
        head_dim=32, mlp_type="swiglu", dtype="float32",
        quant=QuantConfig(mode=args.quant), attn_impl="full")

    mesh = make_host_mesh(model=2)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=25,
                         ckpt_dir=args.ckpt_dir, log_every=10, lr=1e-3,
                         warmup=20, microbatch=args.microbatch,
                         grad_compression=args.grad_compression)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0)
    trainer = Trainer(cfg, tcfg, mesh)
    params, hist = trainer.run(data)
    print(f"first-10 mean loss {sum(hist[:10])/max(len(hist[:10]),1):.4f} -> "
          f"last-10 mean loss {sum(hist[-10:])/max(len(hist[-10:]),1):.4f}")
    if trainer.straggler_events:
        print(f"straggler events at steps: {trainer.straggler_events}")


if __name__ == "__main__":
    main()
