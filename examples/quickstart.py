"""Quickstart: the LUNA-CIM technique end to end in 60 seconds.

1. the paper's multiplier variants on raw 4-bit codes (incl. the Fig 14
   transient-sim re-enactment: W=0110 x Y sequence);
2. hardware cost/energy/area model (Tables I/II, Figs 15/16/18);
3. a real matmul through the Pallas LUNA kernel;
4. a LunaDense-quantized transformer forward pass (model-level
   ``QuantConfig`` — dynamic quantization of every projection);
5. the serving engine with ``EngineConfig(quant="lut4")`` — 4-bit decode
   weights evaluated through the paper's D&C sub-table LUT gemm (the
   ``--quant lut4`` flag on both serving CLIs).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core.layers import QuantConfig
from repro.core.luna import LunaMode, luna_product
from repro.kernels.luna_mm.ops import luna_matmul_f32_kernel

print("=" * 66)
print("1. LUNA multiplier variants (paper Figs 1-10)")
print("=" * 66)
w, y = 0b0110, 0b1011            # 6 x 11
for mode in LunaMode:
    z = int(luna_product(jnp.int32(w), jnp.int32(y), 4, mode))
    tag = "exact" if LunaMode(mode).is_exact else f"err={w*y-z:+d}"
    print(f"  {mode.value:>14}: {w} x {y} = {z:3d}  ({tag})")

print("\n  Fig 14 re-enactment: W=0110 fixed, Y applied sequentially")
for y_seq in (0b1010, 0b1011, 0b0011, 0b1100):
    z = int(luna_product(jnp.int32(w), jnp.int32(y_seq), 4, LunaMode.OPT_DC))
    print(f"    Y={y_seq:04b} -> OUT={z:08b} ({z})")

print()
print("=" * 66)
print("2. Hardware cost model (Tables I/II, Figs 15/16/18)")
print("=" * 66)
for bits in (4, 8, 16):
    conv = cm.conventional_cost(bits)
    opt = cm.opt_dc_cost(bits)
    print(f"  {bits:2d}b: conventional {conv.srams:>8} SRAMs -> "
          f"optimized D&C {opt.srams:>4} SRAMs "
          f"({conv.srams / opt.srams:.0f}x less storage)")
area = cm.area_report(4)
print(f"  area: optimized D&C is "
      f"{area['opt_dc']['area_vs_conventional']:.1f}x smaller (paper: ~3.7x)")
en = cm.energy_report()
print(f"  energy: multiplier = {en['mux_multiplier_J']*1e15:.2f} fJ "
      f"= {en['multiplier_share']*100:.4f}% of SRAM write (paper: 0.0276%)")
print(f"  array overhead: {cm.array_overhead(4)['overhead_fraction']*100:.0f}%"
      " (paper: 32%)")

print()
print("=" * 66)
print("3. Float matmul through the Pallas LUNA kernel (interpret mode)")
print("=" * 66)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
wm = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
ref = x @ wm
for mode in ("opt_dc", "approx_dc", "approx_dc2"):
    out = luna_matmul_f32_kernel(x, wm, mode=mode, interpret=True)
    rel = float(jnp.abs(out - ref).mean() / jnp.abs(ref).mean())
    print(f"  {mode:>10}: mean rel err vs f32 = {rel:.4f}")

print()
print("=" * 66)
print("4. A transformer under LUNA quantization (reduced yi-9b)")
print("=" * 66)
from repro.models.registry import get_config, get_model  # noqa: E402

for mode in ("bf16", "luna_dc", "luna_approx"):
    cfg = get_config("yi-9b").reduced(quant=QuantConfig(mode=mode))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)))
    loss, _ = model.loss(params, {"tokens": toks, "labels": toks})
    print(f"  quant={mode:>12}: loss {float(loss):.4f}")

print()
print("=" * 66)
print('5. Serving with EngineConfig(quant="lut4"): 4-bit decode weights')
print("=" * 66)
from repro.serve.config import EngineConfig  # noqa: E402
from repro.serve.engine import Engine, Request  # noqa: E402

cfg = get_config("yi-9b").reduced(dtype="float32")
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
for quant in (None, "lut4"):
    engine = Engine(cfg, params,
                    EngineConfig(max_batch=2, max_seq=48, quant=quant))
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
                    max_new=6)
            for i in range(2)]
    stats = engine.serve(reqs)
    print(f"  quant={str(quant):>5}: {stats['decode_tokens']} decode tok, "
          f"outputs {[r.out[:3] for r in reqs]}")
print("\nDone.")
