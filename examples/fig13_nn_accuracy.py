"""Paper Fig 13 end-to-end: train MLPs whose forward pass uses each LUNA
multiplier mode (QAT via STE) and compare final task MAE — the paper's
"separate neural networks for each method" experiment.

Extended with the serving-side PTQ columns: the bf16-trained ("ideal") net
re-evaluated with its weights frozen to 4-bit ``QuantizedWeight`` leaves —
exactly what ``EngineConfig(quant=...)`` does to decode projections.

* affine pair (``lut4`` vs ``int4``): both reconstruct the same uniform
  grid, so their MAE is identical; documented bound
  ``MAE(ptq) <= PTQ_MAE_BOUND * MAE(ideal)``.
* non-affine pair (``nf4`` vs the direct full-table NF4 dequant oracle):
  the least-squares D&C split plus the per-code residual correction
  recovers the codebook exactly up to float rounding, so the documented
  bound is ``|MAE(nf4) - MAE(nf4_direct)| <= NF4_DC_VS_DIRECT_TOL``.
* pruned residual (``nf4p``): dropping small residual entries trades
  table bytes for a bounded MAE delta,
  ``MAE(nf4p) <= MAE(nf4) + NF4P_MAE_DELTA_BOUND``; the harness reports
  the residual-table bytes saved alongside.

Run:  PYTHONPATH=src python examples/fig13_nn_accuracy.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lut import prune_residual, residual_table_bytes
from repro.core.quant import (NF4P_PRUNE_THRESHOLD, quantize_weight,
                              ste_luna_matmul)
from repro.kernels.lut_gemm.ops import quantized_matmul

MODES = ["ideal", "opt_dc", "approx_dc2", "approx_dc"]

#: documented PTQ accuracy bound: frozen-4-bit MAE vs the bf16-trained MAE
PTQ_MAE_BOUND = 1.25

#: documented bound: residual-corrected D&C NF4 vs direct full-table NF4
#: dequant — the correction is exact up to float rounding, so the two MAEs
#: may differ only by accumulation noise.
NF4_DC_VS_DIRECT_TOL = 1e-4

#: documented bound on the MAE cost of pruning the NF4 residual sub-table
#: at ``NF4P_PRUNE_THRESHOLD`` (absolute MAE delta vs unpruned nf4).
NF4P_MAE_DELTA_BOUND = 0.05


def make_data(n=512, d=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w_true = rng.normal(size=(d, 1)).astype(np.float32)
    y = np.tanh(x @ w_true) + 0.05 * rng.normal(size=(n, 1))
    return jnp.asarray(x), jnp.asarray(y.astype(np.float32))


def mlp_fwd(params, x, mode):
    mm = ((lambda a, b: a @ b) if mode == "ideal"
          else (lambda a, b: ste_luna_matmul(a, b, mode, 4)))
    h = jnp.tanh(mm(x, params["w1"]) + params["b1"])
    return mm(h, params["w2"]) + params["b2"]


def train_one(mode, steps=300, lr=3e-2):
    x, y = make_data()
    rng = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    params = {"w1": jax.random.normal(k1, (8, 16)) * 0.3,
              "b1": jnp.zeros((16,)),
              "w2": jax.random.normal(k2, (16, 1)) * 0.3,
              "b2": jnp.zeros((1,))}

    @jax.jit
    def step(params):
        def loss_fn(p):
            return jnp.mean((mlp_fwd(p, x, mode) - y) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, loss

    for _ in range(steps):
        params, loss = step(params)
    mae = float(jnp.abs(mlp_fwd(params, x, mode) - y).mean())
    return mae, params


def ptq_mae(params, kernel="lut_dc", prune_threshold=None):
    """MAE of the bf16-trained net with weights frozen to 4-bit codes —
    the serving engine's ``quant="lut4"|"int4"|"nf4"|"nf4p"`` transform."""
    x, y = make_data()
    q1 = quantize_weight(params["w1"], kernel, prune_threshold)
    q2 = quantize_weight(params["w2"], kernel, prune_threshold)
    h = jnp.tanh(quantized_matmul(x, q1) + params["b1"])
    out = quantized_matmul(h, q2) + params["b2"]
    return float(jnp.abs(out - y).mean())


def nf4p_table_report(threshold=NF4P_PRUNE_THRESHOLD):
    """Residual sub-table cost: dense (16,) f32 vs pruned sparse storage."""
    from repro.core.lut import NF4_CODEBOOK, dc_decompose_codebook
    _, _, residual = dc_decompose_codebook(jnp.asarray(NF4_CODEBOOK))
    kept_idx, _ = prune_residual(residual, threshold)
    dense, pruned = residual_table_bytes(int(kept_idx.shape[0]))
    return {"kept": int(kept_idx.shape[0]), "dense_bytes": dense,
            "pruned_bytes": pruned, "bytes_saved": dense - pruned}


def main():
    print("mode,final_MAE  (paper Fig 13: exact < ApproxD&C2 < ApproxD&C)")
    results = {}
    trained = {}
    for mode in MODES:
        mae, params = train_one(mode)
        results[mode] = mae
        trained[mode] = params
        print(f"  {mode:>10}: MAE {mae:.4f}")
    ptq = (("lut_dc", None, "ptq_lut4"), ("dequant", None, "ptq_int4"),
           ("nf4_dc", None, "ptq_nf4"),
           ("nf4_dequant", None, "ptq_nf4_direct"),
           ("nf4_dc", NF4P_PRUNE_THRESHOLD, "ptq_nf4p"))
    for kernel, prune, label in ptq:
        results[label] = ptq_mae(trained["ideal"], kernel, prune)
        print(f"  {label:>14}: MAE {results[label]:.4f}")
    tab = nf4p_table_report()
    print(f"  nf4p residual table: kept {tab['kept']}/16 entries, "
          f"{tab['pruned_bytes']}B vs {tab['dense_bytes']}B dense "
          f"({tab['bytes_saved']}B saved)")
    assert results["ideal"] <= results["approx_dc"] * 1.2
    assert results["ptq_lut4"] <= results["ideal"] * PTQ_MAE_BOUND, \
        (results["ptq_lut4"], results["ideal"])
    assert results["ptq_lut4"] == results["ptq_int4"]   # same affine grid
    # non-affine: residual-corrected D&C matches direct dequant up to
    # float rounding; pruning costs a bounded MAE delta and saves bytes
    assert abs(results["ptq_nf4"] - results["ptq_nf4_direct"]) \
        <= NF4_DC_VS_DIRECT_TOL, \
        (results["ptq_nf4"], results["ptq_nf4_direct"])
    assert results["ptq_nf4p"] <= results["ptq_nf4"] + NF4P_MAE_DELTA_BOUND, \
        (results["ptq_nf4p"], results["ptq_nf4"])
    assert tab["bytes_saved"] > 0
    results["nf4p_table"] = tab
    return results


if __name__ == "__main__":
    main()
