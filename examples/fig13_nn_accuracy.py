"""Paper Fig 13 end-to-end: train MLPs whose forward pass uses each LUNA
multiplier mode (QAT via STE) and compare final task MAE — the paper's
"separate neural networks for each method" experiment.

Extended with the serving-side PTQ column: the bf16-trained ("ideal") net
re-evaluated with its weights frozen to 4-bit ``QuantizedWeight`` leaves —
exactly what ``EngineConfig(quant="lut4"|"int4")`` does to decode
projections.  Both evaluation strategies (D&C sub-table LUT vs direct
dequant) reconstruct the same affine grid, so their MAE is identical; the
documented accuracy bound (see docs/quantization.md) is
``MAE(ptq) <= PTQ_MAE_BOUND * MAE(ideal)``.

Run:  PYTHONPATH=src python examples/fig13_nn_accuracy.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import quantize_weight, ste_luna_matmul
from repro.kernels.lut_gemm.ops import quantized_matmul

MODES = ["ideal", "opt_dc", "approx_dc2", "approx_dc"]

#: documented PTQ accuracy bound: frozen-4-bit MAE vs the bf16-trained MAE
PTQ_MAE_BOUND = 1.25


def make_data(n=512, d=8, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    w_true = rng.normal(size=(d, 1)).astype(np.float32)
    y = np.tanh(x @ w_true) + 0.05 * rng.normal(size=(n, 1))
    return jnp.asarray(x), jnp.asarray(y.astype(np.float32))


def mlp_fwd(params, x, mode):
    mm = ((lambda a, b: a @ b) if mode == "ideal"
          else (lambda a, b: ste_luna_matmul(a, b, mode, 4)))
    h = jnp.tanh(mm(x, params["w1"]) + params["b1"])
    return mm(h, params["w2"]) + params["b2"]


def train_one(mode, steps=300, lr=3e-2):
    x, y = make_data()
    rng = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    params = {"w1": jax.random.normal(k1, (8, 16)) * 0.3,
              "b1": jnp.zeros((16,)),
              "w2": jax.random.normal(k2, (16, 1)) * 0.3,
              "b2": jnp.zeros((1,))}

    @jax.jit
    def step(params):
        def loss_fn(p):
            return jnp.mean((mlp_fwd(p, x, mode) - y) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
        return params, loss

    for _ in range(steps):
        params, loss = step(params)
    mae = float(jnp.abs(mlp_fwd(params, x, mode) - y).mean())
    return mae, params


def ptq_mae(params, kernel="lut_dc"):
    """MAE of the bf16-trained net with weights frozen to 4-bit codes —
    the serving engine's ``quant="lut4"`` / ``"int4"`` transform."""
    x, y = make_data()
    q1 = quantize_weight(params["w1"], kernel)
    q2 = quantize_weight(params["w2"], kernel)
    h = jnp.tanh(quantized_matmul(x, q1) + params["b1"])
    out = quantized_matmul(h, q2) + params["b2"]
    return float(jnp.abs(out - y).mean())


def main():
    print("mode,final_MAE  (paper Fig 13: exact < ApproxD&C2 < ApproxD&C)")
    results = {}
    trained = {}
    for mode in MODES:
        mae, params = train_one(mode)
        results[mode] = mae
        trained[mode] = params
        print(f"  {mode:>10}: MAE {mae:.4f}")
    for kernel, label in (("lut_dc", "ptq_lut4"), ("dequant", "ptq_int4")):
        results[label] = ptq_mae(trained["ideal"], kernel)
        print(f"  {label:>10}: MAE {results[label]:.4f}")
    assert results["ideal"] <= results["approx_dc"] * 1.2
    assert results["ptq_lut4"] <= results["ideal"] * PTQ_MAE_BOUND, \
        (results["ptq_lut4"], results["ideal"])
    assert results["ptq_lut4"] == results["ptq_int4"]   # same affine grid
    return results


if __name__ == "__main__":
    main()
