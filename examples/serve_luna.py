"""Serve a small model with batched requests through the LUNA-quantized path.

The paper's CiM setting is inference: weights stationary in SRAM, inputs
streamed through the LUT multipliers.  The serving engine is the system
analogue — weights resident, requests streamed through batched prefill and
mixed-depth continuous-batching decode with every projection in the chosen
LUNA mode.  This example also shows the v2 request lifecycle: one request
is streamed token-by-token through its ``RequestHandle``.

``--quant`` is the shared flag registered by ``EngineConfig.add_cli_args``:
``lut4``/``int4`` freeze 4-bit affine decode weights on the engine (the
paper's D&C sub-table LUT gemm on the decode hot path), ``nf4``/``nf4p``
freeze non-affine NF4 weights (D&C + full or pruned residual correction);
any other spelling (``luna_*``, ``int8``, ``lut_nf4``, ``bf16``) is a
model-level ``QuantConfig`` mode applied dynamically to every projection.

``--spec ngram|self_lut`` (greedy-only) turns on speculative decoding:
drafts verified in one batched window, accepted prefixes emitted in
bulk, token-identical to plain greedy — see ``docs/speculative.md``.

Run:  PYTHONPATH=src python examples/serve_luna.py --quant luna_approx2 \
          --sampling top_k --top-k 20
      PYTHONPATH=src python examples/serve_luna.py --quant lut4
      PYTHONPATH=src python examples/serve_luna.py --quant nf4
      PYTHONPATH=src python examples/serve_luna.py --quant nf4p \
          --spec self_lut            # drafts alias the decode LUT tree
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=2")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.layers import QuantConfig  # noqa: E402
from repro.models.registry import get_config, get_model  # noqa: E402
from repro.serve.config import ENGINE_QUANT_MODES, EngineConfig  # noqa: E402
from repro.serve.engine import Engine, Request  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=12)
    EngineConfig.add_cli_args(ap)
    ap.set_defaults(max_batch=4, max_seq=96, quant="luna_approx")
    args = ap.parse_args()

    model_mode = (args.quant if args.quant not in ENGINE_QUANT_MODES
                  else "bf16")
    cfg = get_config("yi-9b").reduced(quant=QuantConfig(mode=model_mode))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, EngineConfig.from_args(args))

    rng = np.random.default_rng(0)
    # deliberately mixed prompt lengths: the engine buckets them for prefill
    # and decodes them at per-slot positions on one slab
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        1, cfg.vocab_size, int(rng.integers(3, 9))).tolist(),
                    max_new=args.max_new,
                    priority=1 if i == 0 else 0)
            for i in range(args.requests)]
    stats = engine.serve(reqs)
    print(f"served {len(reqs)} requests in {stats['ticks']} ticks "
          f"({stats['wall_s']:.1f}s wall, quant={args.quant}, "
          f"sampling={args.sampling})")
    print(f"  prefill {stats['prefill_tok_s']:.0f} tok/s over "
          f"{stats['prefill_calls']} bucket calls | decode "
          f"{stats['decode_tok_s']:.0f} tok/s | slot occupancy "
          f"{stats['occupancy']:.0%}")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt} -> {r.out}")
    assert stats["done"]

    # v2 lifecycle: stream one more request incrementally off its handle
    handle = engine.submit(Request(
        rid=99, prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
        max_new=6, priority=1))
    streamed = list(handle.tokens())
    print(f"  streamed req 99: {streamed}")
    assert streamed == handle.out


if __name__ == "__main__":
    main()
