"""Public wrappers: codebook quantize + LUT GEMM (weight-only 4-bit)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lut import NF4_CODEBOOK
from repro.kernels.lut_gemm.lut_gemm import lut_gemm


def codebook_quantize(w: jax.Array, codebook: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Per-output-channel absmax normalize + nearest-codebook-entry encode."""
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)
    wn = w / scale
    codes = jnp.argmin(jnp.abs(wn[..., None] - codebook), axis=-1)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nf4_matmul_kernel(x: jax.Array, w: jax.Array,
                      interpret: bool = True) -> jax.Array:
    """Float GEMM with NF4 codebook weights through the Pallas LUT kernel."""
    cb = jnp.asarray(NF4_CODEBOOK)
    codes, scale = codebook_quantize(w, cb)
    m, k = x.shape
    n = w.shape[1]
    bm = _fit(m)
    bn = _fit(n)
    bk = _fit(k)
    xp = jnp.pad(x, [(0, (-m) % bm), (0, (-k) % bk)])
    cp = jnp.pad(codes, [(0, (-k) % bk), (0, (-n) % bn)])
    sp = jnp.pad(scale, [(0, (-n) % bn)])
    out = lut_gemm(xp, cp, cb, sp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def _fit(d: int, base: int = 8) -> int:
    b = base
    while b * 2 <= d and b < 256:
        b *= 2
    return b
