"""Public wrappers: codebook quantize + LUT GEMM (weight-only 4-bit).

Four entry points over the LUT kernels:

* :func:`nf4_matmul_kernel` — NF4 codebook weights through the full-table
  Pallas kernel (paper Fig 1 select tree, programmable codebook).
* :func:`lut4_matmul_kernel` — uniform-int4 weights through the D&C
  sub-table Pallas kernel (paper Figs 2/3: two 4-entry tables, 6 selects).
* :func:`nf4dc_matmul_kernel` — NF4 weights through the residual-corrected
  D&C Pallas kernel (6-select mux + per-code residual epilogue — the
  non-affine extension; a prune threshold reproduces ``quant="nf4p"``).
* :func:`quantized_matmul` — the serving decode hot path: a frozen
  :class:`~repro.core.quant.QuantizedWeight` evaluated with jnp primitives
  (jit-compatible on every backend; the Pallas kernels above implement the
  same math for TPU).  Dispatches on the container's static ``kernel`` tag:
  ``"lut_dc"`` reconstructs the weight by summing the two D&C sub-table
  selects through ``core.lut.mux_tree_select`` (3 + 3 muxes — the paper's
  area argument); ``"dequant"`` is the conventional-math baseline
  ``(q - z_w) * s_w`` (both reconstruct the identical affine grid, so
  engine tokens match bit-for-bit between ``quant="lut4"`` and ``"int4"``);
  ``"nf4_dc"`` adds the per-code residual gather to the D&C sum (non-affine
  NF4, exact up to float rounding with the full residual, bounded-error
  with a pruned one); ``"nf4_dequant"`` is the direct full-table NF4
  lookup the residual path is pinned against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.lut import NF4_CODEBOOK, codebook_dequant
from repro.core.quant import QuantizedWeight, dequantize, quantize_weight
from repro.kernels.lut_gemm.lut_gemm import (lut_gemm, lut_gemm_dc,
                                             lut_gemm_dc_res)


def codebook_quantize(w: jax.Array, codebook: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Per-output-channel absmax normalize + nearest-codebook-entry encode."""
    scale = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)
    wn = w / scale
    codes = jnp.argmin(jnp.abs(wn[..., None] - codebook), axis=-1)
    return codes.astype(jnp.int8), scale.astype(jnp.float32)


def quantized_matmul(x: jax.Array, qw: QuantizedWeight) -> jax.Array:
    """``x @ dequant(qw)`` — the engine's quantized decode-step matmul.

    ``x``: (..., K) float; ``qw.codes``: (K, N) (scan-stacked leaves are
    sliced to 2-D before reaching here).  Output dtype follows ``x``.
    Dispatches on the container's static ``kernel`` tag: the affine pair
    (``lut_dc`` / ``dequant``) reconstructs one identical grid; the NF4
    pair evaluates the non-affine codebook either as the 6-select D&C sum
    plus a per-code residual gather (``nf4_dc`` — the residual is the
    least-squares correction of ``core.lut.dc_decompose_codebook``, zeroed
    at pruned codes under ``quant="nf4p"``) or as the conventional
    full-table lookup (``nf4_dequant``, the 15-select oracle).
    """
    assert qw.codes.ndim == 2, (
        f"quantized_matmul expects a sliced 2-D weight, got "
        f"{qw.codes.shape}; scan-stacked leaves are sliced by lax.scan")
    q = qw.codes.astype(jnp.int32)
    if qw.kernel == "lut_dc":
        w_q = (codebook_dequant(q >> 2, qw.hi_tab)
               + codebook_dequant(q & 3, qw.lo_tab))
        w = (w_q - qw.zero_point[None, :]) * qw.scale[None, :]
    elif qw.kernel == "nf4_dc":
        w_q = (codebook_dequant(q >> 2, qw.hi_tab)
               + codebook_dequant(q & 3, qw.lo_tab)
               + codebook_dequant(q, qw.residual))
        w = (w_q - qw.zero_point[None, :]) * qw.scale[None, :]
    elif qw.kernel == "nf4_dequant":        # full-table oracle (15 selects)
        w = codebook_dequant(q, jnp.asarray(NF4_CODEBOOK)) * qw.scale[None, :]
    else:                                   # "dequant": conventional math
        w = dequantize(q, qw.qparams)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def nf4_matmul_kernel(x: jax.Array, w: jax.Array,
                      interpret: bool = True) -> jax.Array:
    """Float GEMM with NF4 codebook weights through the Pallas LUT kernel."""
    cb = jnp.asarray(NF4_CODEBOOK)
    codes, scale = codebook_quantize(w, cb)
    m, k = x.shape
    n = w.shape[1]
    bm = _fit(m)
    bn = _fit(n)
    bk = _fit(k)
    xp = jnp.pad(x, [(0, (-m) % bm), (0, (-k) % bk)])
    cp = jnp.pad(codes, [(0, (-k) % bk), (0, (-n) % bn)])
    sp = jnp.pad(scale, [(0, (-n) % bn)])
    out = lut_gemm(xp, cp, cb, sp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def lut4_matmul_kernel(x: jax.Array, w: jax.Array,
                       interpret: bool = True) -> jax.Array:
    """Float GEMM with uniform-int4 weights through the D&C Pallas kernel.

    Quantizes ``w`` with :func:`~repro.core.quant.quantize_weight` (the same
    calibration the engine freezes at construction) and evaluates through
    the six-select sub-table kernel.  Pads every dim to the fitted block.
    """
    qw = quantize_weight(w, kernel="lut_dc")
    m, k = x.shape
    n = w.shape[1]
    bm = _fit(m)
    bn = _fit(n)
    bk = _fit(k)
    xp = jnp.pad(x, [(0, (-m) % bm), (0, (-k) % bk)])
    cp = jnp.pad(qw.codes, [(0, (-k) % bk), (0, (-n) % bn)])
    zp = jnp.pad(qw.zero_point, [(0, (-n) % bn)])
    sp = jnp.pad(qw.scale, [(0, (-n) % bn)])
    out = lut_gemm_dc(xp, cp, qw.hi_tab, qw.lo_tab, zp, sp,
                      bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("prune_threshold", "interpret"))
def nf4dc_matmul_kernel(x: jax.Array, w: jax.Array,
                        prune_threshold: float | None = None,
                        interpret: bool = True) -> jax.Array:
    """Float GEMM with NF4 weights through the residual-corrected D&C
    Pallas kernel (6-select mux + per-code residual epilogue).

    Quantizes ``w`` with :func:`~repro.core.quant.quantize_weight` in
    ``nf4_dc`` mode (the same transform ``EngineConfig(quant="nf4")``
    freezes at engine construction; a ``prune_threshold`` reproduces
    ``"nf4p"``) and evaluates through :func:`lut_gemm_dc_res`.  Pads every
    dim to the fitted block.
    """
    qw = quantize_weight(w, kernel="nf4_dc", prune_threshold=prune_threshold)
    m, k = x.shape
    n = w.shape[1]
    bm = _fit(m)
    bn = _fit(n)
    bk = _fit(k)
    xp = jnp.pad(x, [(0, (-m) % bm), (0, (-k) % bk)])
    cp = jnp.pad(qw.codes, [(0, (-k) % bk), (0, (-n) % bn)])
    zp = jnp.pad(qw.zero_point, [(0, (-n) % bn)])
    sp = jnp.pad(qw.scale, [(0, (-n) % bn)])
    out = lut_gemm_dc_res(xp, cp, qw.hi_tab, qw.lo_tab, qw.residual, zp, sp,
                          bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:m, :n]


def _fit(d: int, base: int = 8) -> int:
    b = base
    while b * 2 <= d and b < 256:
        b *= 2
    return b
