"""Pure-jnp oracle for the codebook LUT GEMM."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_gemm_ref(x: jax.Array, w_codes: jax.Array, codebook: jax.Array,
                 scale: jax.Array) -> jax.Array:
    w = codebook[w_codes.astype(jnp.int32)] * scale[None, :]
    return (x.astype(jnp.float32) @ w).astype(jnp.float32)
