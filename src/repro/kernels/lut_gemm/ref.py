"""Pure-jnp oracles for the codebook LUT GEMMs.

Two reference semantics, matching the two Pallas kernels:

* :func:`lut_gemm_ref` — full-table evaluation: each 4-bit code indexes a
  16-entry codebook directly (paper Fig 1, the conventional LUT whose
  hardware cost is fifteen 2:1 muxes per output bit).
* :func:`lut_gemm_dc_ref` — divide-and-conquer evaluation (paper Figs 2/3):
  the code splits into 2-bit digits ``q = 4*q_hi + q_lo`` and the table
  value is the SUM of two 4-entry sub-table selects, six muxes total —
  the decomposition behind the paper's ~3.7x LUT-area saving.  With the
  affine sub-tables produced by ``core.quant.quantize_weight`` the two
  references reconstruct identical weights.
* :func:`lut_gemm_dc_res_ref` — residual-corrected D&C (non-affine NF4):
  the 6-select sum plus a per-code residual gather.  Unlike the affine
  refs (which fold the scale into the weight before the matmul — the
  order ``ops.quantized_matmul`` uses), this one mirrors the Pallas
  kernel's epilogue order exactly (zero-point pre-matmul, scale after),
  so kernel and reference are BITWISE-identical on single-K-block shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lut_gemm_ref(x: jax.Array, w_codes: jax.Array, codebook: jax.Array,
                 scale: jax.Array) -> jax.Array:
    w = codebook[w_codes.astype(jnp.int32)] * scale[None, :]
    return (x.astype(jnp.float32) @ w).astype(jnp.float32)


def lut_gemm_dc_ref(x: jax.Array, w_codes: jax.Array, hi_tab: jax.Array,
                    lo_tab: jax.Array, zero_point: jax.Array,
                    scale: jax.Array) -> jax.Array:
    """``x @ ((HI[q>>2] + LO[q&3] - zp) * scale)`` — D&C sub-table dequant.

    ``w_codes``: (K, N) int8 codes in [0, 16); ``hi_tab``/``lo_tab``: (4,)
    code-space sub-tables; ``zero_point``/``scale``: (N,) per-channel
    affine params.  Returns (M, N) f32.
    """
    q = w_codes.astype(jnp.int32)
    w_q = hi_tab[q >> 2] + lo_tab[q & 3]
    w = (w_q - zero_point[None, :]) * scale[None, :]
    return (x.astype(jnp.float32) @ w).astype(jnp.float32)


def lut_gemm_dc_res_ref(x: jax.Array, w_codes: jax.Array, hi_tab: jax.Array,
                        lo_tab: jax.Array, residual: jax.Array,
                        zero_point: jax.Array, scale: jax.Array
                        ) -> jax.Array:
    """``x @ (HI[q>>2] + LO[q&3] + RES[q] - zp)`` scaled in the epilogue —
    the residual-corrected D&C dequant (non-affine NF4).

    ``w_codes``: (K, N) int8 codes in [0, 16); ``hi_tab``/``lo_tab``: (4,)
    least-squares sub-tables; ``residual``: (16,) per-code correction
    (zeros at pruned codes); ``zero_point``/``scale``: (N,) per-channel.
    Operation order mirrors ``lut_gemm.lut_gemm_dc_res`` exactly (see its
    docstring) — the bitwise-parity contract.  Returns (M, N) f32.
    """
    q = w_codes.astype(jnp.int32)
    w_q = (hi_tab[q >> 2] + lo_tab[q & 3]) + residual[q]
    w = w_q - zero_point[None, :]
    acc = jax.lax.dot_general(x.astype(jnp.float32), w,
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return acc * scale[None, :]
