"""Pallas TPU kernels: programmable-LUT (codebook) weight-only GEMM.

The "programmable" half of LUNA-CIM: weights are 4-bit *codes* into an
arbitrary 16-entry codebook (uniform int4, NF4, or any learned table).  Two
kernels implement the paper's two select-tree organizations:

* :func:`lut_gemm` — full-table (paper Fig 1): each (bk, bn) weight tile is
  dequantized in VMEM through a binary mux tree of ``2**b - 1 = 15`` vector
  selects on the code bits, the exact analogue of the paper's fifteen 2:1
  muxes, then fed to the MXU.
* :func:`lut_gemm_dc` — divide-and-conquer (paper Figs 2/3): the 4-bit code
  splits into 2-bit digits ``q = 4*q_hi + q_lo`` and the table value is the
  sum of two 4-entry sub-table selects — ``2 * (2**2 - 1) = 6`` muxes
  instead of 15, the select-tree shrink behind the paper's ~3.7x LUT-area
  saving.  Per-channel zero-points are subtracted pre-MXU (the ``z_w``
  correction term of the integer-GEMM identity in ``core.quant``), scales
  applied in the epilogue.
* :func:`lut_gemm_dc_res` — residual-corrected D&C for NON-AFFINE
  codebooks (NF4): the 6-select sum only spans separable tables, so the
  least-squares residual of ``core.lut.dc_decompose_codebook`` is gathered
  per code and added after the mux tree.  With the full residual the
  reconstruction is exact up to float rounding; with a pruned residual
  (``quant="nf4p"``) dropped codes fall through to the pure HI+LO sum and
  the table trades capacity for a bounded accuracy cost.

Memory layout per grid step: x tile (bm, bk) bf16/f32, packed codes tile
(bk, bn) int8, dequantized tile (bk, bn) f32 (transient), accumulator
(bm, bn) f32 in VMEM scratch.  Per-output-channel scales are applied in the
epilogue on the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _mux_tree_dequant(codes: jax.Array, cb_ref) -> jax.Array:
    """Paper's mux tree: 15 binary selects on the 4 code bits.

    ``codes``: (bk, bn) int8 in [0, 16); ``cb_ref``: (1, 16) codebook.
    """
    leaves = [cb_ref[0, j] for j in range(16)]   # scalar leaves
    bits = [((codes >> b) & 1).astype(bool) for b in range(4)]
    level = leaves
    for b in range(4):                            # 8 + 4 + 2 + 1 = 15 selects
        level = [jnp.where(bits[b], level[2 * i + 1], level[2 * i])
                 for i in range(len(level) // 2)]
    return level[0]


def _lut_gemm_kernel(x_ref, codes_ref, cb_ref, scale_ref, o_ref, acc_ref, *,
                     nk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _mux_tree_dequant(codes_ref[...], cb_ref)          # (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...] * scale_ref[...]         # (1, bn) broadcast


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_gemm(x: jax.Array, w_codes: jax.Array, codebook: jax.Array,
             scale: jax.Array, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
             bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """``x @ (codebook[w_codes] * scale)`` with in-VMEM LUT dequant.

    x: (M, K) float; w_codes: (K, N) int8; codebook: (16,) f32;
    scale: (N,) f32 per-output-channel.  Returns (M, N) f32.
    """
    m, k = x.shape
    k2, n = w_codes.shape
    assert k == k2 and codebook.shape == (16,)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_lut_gemm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 16), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, codebook.reshape(1, 16), scale.reshape(1, n))


def _dc_mux_dequant(codes: jax.Array, hi_ref, lo_ref) -> jax.Array:
    """Paper's D&C select tree: 3 + 3 binary selects on the 2-bit digits.

    ``codes``: (bk, bn) int8 in [0, 16); ``hi_ref``/``lo_ref``: (1, 4)
    code-space sub-tables.  Returns ``HI[codes >> 2] + LO[codes & 3]``.
    """
    def sel4(idx, tab_ref):
        leaves = [tab_ref[0, j] for j in range(4)]
        b0 = (idx & 1).astype(bool)
        b1 = ((idx >> 1) & 1).astype(bool)
        lo = jnp.where(b0, leaves[1], leaves[0])
        hi = jnp.where(b0, leaves[3], leaves[2])
        return jnp.where(b1, hi, lo)

    return sel4((codes >> 2) & 3, hi_ref) + sel4(codes & 3, lo_ref)


def _lut_gemm_dc_kernel(x_ref, codes_ref, hi_ref, lo_ref, zp_ref, scale_ref,
                        o_ref, acc_ref, *, nk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w_q = _dc_mux_dequant(codes_ref[...], hi_ref, lo_ref)   # (bk, bn) f32
    w = w_q - zp_ref[...]                                   # (1, bn) bcast
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...] * scale_ref[...]          # (1, bn) bcast


def _lut_gemm_dc_res_kernel(x_ref, codes_ref, hi_ref, lo_ref, res_ref,
                            zp_ref, scale_ref, o_ref, acc_ref, *, nk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...]
    # 6-select D&C mux, then the per-code residual gather (a 16:1 select
    # on the residual table — narrow storage in CIM, zeros where pruned)
    w_q = (_dc_mux_dequant(codes, hi_ref, lo_ref)
           + _mux_tree_dequant(codes, res_ref))          # (bk, bn) f32
    w = w_q - zp_ref[...]                                # (1, bn) bcast
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...] * scale_ref[...]       # (1, bn) bcast


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_gemm_dc_res(x: jax.Array, w_codes: jax.Array, hi_tab: jax.Array,
                    lo_tab: jax.Array, residual: jax.Array,
                    zero_point: jax.Array, scale: jax.Array, *,
                    bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                    bk: int = DEFAULT_BK, interpret: bool = False
                    ) -> jax.Array:
    """``x @ ((HI[q>>2] + LO[q&3] + RES[q] - zp) * scale)`` — the
    residual-corrected D&C dequant for NON-AFFINE codebooks (NF4).

    x: (M, K) float; w_codes: (K, N) int8; hi_tab/lo_tab: (4,) f32
    least-squares sub-tables; residual: (16,) f32 per-code correction
    (zeros at pruned codes); zero_point/scale: (N,) f32 per-output-channel.
    Returns (M, N) f32.  The epilogue order (residual add after the
    6-select mux, zero-point pre-MXU, scale on the final K step) is the
    contract :func:`repro.kernels.lut_gemm.ref.lut_gemm_dc_res_ref`
    mirrors operation-for-operation, so kernel and reference agree
    bitwise on single-K-block shapes.
    """
    m, k = x.shape
    k2, n = w_codes.shape
    assert k == k2 and hi_tab.shape == (4,) and lo_tab.shape == (4,)
    assert residual.shape == (16,)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_lut_gemm_dc_res_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 16), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, hi_tab.reshape(1, 4), lo_tab.reshape(1, 4),
      residual.reshape(1, 16), zero_point.reshape(1, n), scale.reshape(1, n))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_gemm_dc(x: jax.Array, w_codes: jax.Array, hi_tab: jax.Array,
                lo_tab: jax.Array, zero_point: jax.Array, scale: jax.Array,
                *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """``x @ ((HI[q>>2] + LO[q&3] - zp) * scale)`` with D&C in-VMEM dequant.

    x: (M, K) float; w_codes: (K, N) int8; hi_tab/lo_tab: (4,) f32 code-space
    sub-tables; zero_point/scale: (N,) f32 per-output-channel.  Returns
    (M, N) f32.  Six selects per tile vs fifteen in :func:`lut_gemm`.
    """
    m, k = x.shape
    k2, n = w_codes.shape
    assert k == k2 and hi_tab.shape == (4,) and lo_tab.shape == (4,)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_lut_gemm_dc_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, hi_tab.reshape(1, 4), lo_tab.reshape(1, 4),
      zero_point.reshape(1, n), scale.reshape(1, n))
