"""Pallas TPU kernel: programmable-LUT (codebook) weight-only GEMM.

The "programmable" half of LUNA-CIM: weights are 4-bit *codes* into an
arbitrary 16-entry codebook (uniform int4, NF4, or any learned table).  The
kernel dequantizes each (bk, bn) weight tile in VMEM through the paper's
binary mux tree — ``2**b - 1 = 15`` vector selects on the code bits, the
exact analogue of the paper's fifteen 2:1 muxes — then feeds the MXU.

Memory layout per grid step: x tile (bm, bk) bf16/f32, packed codes tile
(bk, bn) int8, dequantized tile (bk, bn) f32 (transient), accumulator
(bm, bn) f32 in VMEM scratch.  Per-output-channel scales are applied in the
epilogue on the final K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _mux_tree_dequant(codes: jax.Array, cb_ref) -> jax.Array:
    """Paper's mux tree: 15 binary selects on the 4 code bits.

    ``codes``: (bk, bn) int8 in [0, 16); ``cb_ref``: (1, 16) codebook.
    """
    leaves = [cb_ref[0, j] for j in range(16)]   # scalar leaves
    bits = [((codes >> b) & 1).astype(bool) for b in range(4)]
    level = leaves
    for b in range(4):                            # 8 + 4 + 2 + 1 = 15 selects
        level = [jnp.where(bits[b], level[2 * i + 1], level[2 * i])
                 for i in range(len(level) // 2)]
    return level[0]


def _lut_gemm_kernel(x_ref, codes_ref, cb_ref, scale_ref, o_ref, acc_ref, *,
                     nk: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _mux_tree_dequant(codes_ref[...], cb_ref)          # (bk, bn) f32
    x = x_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(k_step == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...] * scale_ref[...]         # (1, bn) broadcast


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def lut_gemm(x: jax.Array, w_codes: jax.Array, codebook: jax.Array,
             scale: jax.Array, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
             bk: int = DEFAULT_BK, interpret: bool = False) -> jax.Array:
    """``x @ (codebook[w_codes] * scale)`` with in-VMEM LUT dequant.

    x: (M, K) float; w_codes: (K, N) int8; codebook: (16,) f32;
    scale: (N,) f32 per-output-channel.  Returns (M, N) f32.
    """
    m, k = x.shape
    k2, n = w_codes.shape
    assert k == k2 and codebook.shape == (16,)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk

    return pl.pallas_call(
        functools.partial(_lut_gemm_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, 16), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_codes, codebook.reshape(1, 16), scale.reshape(1, n))
