"""Pallas TPU kernel: LUNA D&C quantized GEMM (digit-plane formulation).

Computes ``Z[m, n] = sum_k L(W[k, n], Y[m, k])`` where ``L`` is the LUNA
multiplier in one of the paper's modes.  TPU mapping (DESIGN.md section 2):

  * the radix-4 digit split of Y becomes two int8 digit-plane tiles
    (``y >> 2`` and ``y & 3``) staged in VMEM,
  * each "lookup" of the 4-entry table {0, W, 2W, 3W} is an int8 MXU matmul
    of a digit plane against the weight tile (the table is linear in W),
  * the paper's HA/FA shift-add combine is the int32 ``(hi << 2) + lo``,
  * ApproxD&C drops the low plane -> HALF the MXU work,
  * ApproxD&C2 adds ``colsum(W)`` instead -> accumulated per K-tile, a
    VPU-only reduction (the "free bias").

Grid: ``(M/bm, N/bn, K/bk)`` with K innermost; the int32 accumulator lives in
a VMEM scratch tile and is flushed to the output on the last K step — the
standard TPU matmul pipeline shape.  Block sizes default to MXU-aligned
(128, 128) output tiles with a 256-deep K so that the two int8 digit tiles
(2 x 128 x 256 B), the weight tile (256 x 128 B) and the int32 accumulator
(128 x 128 x 4 B) comfortably fit VMEM (~160 KiB working set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.luna import LunaMode

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 256


def _luna_mm_kernel(y_ref, w_ref, o_ref, acc_ref, *, mode: str, nk: int):
    """One (bm, bn) output tile; K streamed over the innermost grid dim."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    y = y_ref[...]                      # (bm, bk) int8 codes in [0, 16)
    w = w_ref[...]                      # (bk, bn) int8 codes in [0, 16)

    dims = (((1,), (0,)), ((), ()))
    hi = (y >> 2).astype(jnp.int8)
    acc = acc_ref[...]
    # MSB-side lookup: digit-plane matmul on the MXU.
    z_hi = jax.lax.dot_general(hi, w, dims, preferred_element_type=jnp.int32)
    if mode in (LunaMode.APPROX_DC.value, LunaMode.APPROX_DC2.value):
        acc += z_hi << 2
        if mode == LunaMode.APPROX_DC2.value:
            # Z_LSB := W  ->  colsum of this K tile, broadcast over rows.
            acc += jnp.sum(w.astype(jnp.int32), axis=0)[None, :]
    elif mode == LunaMode.CONVENTIONAL.value:
        # Full-LUT semantics == one full-width code matmul (exact).
        acc = acc + jax.lax.dot_general(y, w, dims,
                                        preferred_element_type=jnp.int32)
    else:  # exact D&C (dc / opt_dc): both digit planes.
        lo = (y & 3).astype(jnp.int8)
        z_lo = jax.lax.dot_general(lo, w, dims,
                                   preferred_element_type=jnp.int32)
        acc += (z_hi << 2) + z_lo
    acc_ref[...] = acc

    @pl.when(k_step == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk",
                                             "interpret"))
def luna_mm(y_codes: jax.Array, w_codes: jax.Array, *, mode: str = "opt_dc",
            bm: int = DEFAULT_BM, bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
            interpret: bool = False) -> jax.Array:
    """LUNA GEMM on unsigned 4-bit codes carried in int8.

    ``y_codes``: (M, K) int8; ``w_codes``: (K, N) int8; returns (M, N) int32.
    Shapes must be multiples of the block sizes (the ops.py wrapper pads).
    """
    m, k = y_codes.shape
    k2, n = w_codes.shape
    assert k == k2, (y_codes.shape, w_codes.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk
    mode = LunaMode(mode).value

    return pl.pallas_call(
        functools.partial(_luna_mm_kernel, mode=mode, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(y_codes, w_codes)
