"""Pure-jnp oracle for the LUNA GEMM kernel (no Pallas)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.luna import LunaMode


def luna_mm_ref(y_codes: jax.Array, w_codes: jax.Array,
                mode: str = "opt_dc") -> jax.Array:
    """Reference: digit-split int32 math, no tiling, no int8 casts."""
    mode = LunaMode(mode)
    y = y_codes.astype(jnp.int32)
    w = w_codes.astype(jnp.int32)
    hi, lo = y >> 2, y & 3
    if mode == LunaMode.APPROX_DC:
        return (hi @ w) << 2
    if mode == LunaMode.APPROX_DC2:
        return ((hi @ w) << 2) + jnp.sum(w, axis=0)[None, :]
    return y @ w  # all exact modes equal the true product
