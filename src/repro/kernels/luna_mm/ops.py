"""Jit'd public wrappers around the LUNA GEMM Pallas kernel.

Handles shape padding to block multiples and the float-in/float-out
quantize -> integer kernel -> zero-point-correct -> dequantize pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quant import calibrate, quantize
from repro.kernels.luna_mm.luna_mm import luna_mm

_ON_TPU = None


def _interpret_default() -> bool:
    """Pallas TPU kernels run under interpret=True everywhere else."""
    global _ON_TPU
    if _ON_TPU is None:
        _ON_TPU = jax.default_backend() == "tpu"
    return not _ON_TPU


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("mode", "bm", "bn", "bk",
                                             "interpret"))
def luna_mm_codes(y_codes: jax.Array, w_codes: jax.Array, *,
                  mode: str = "opt_dc", bm: int = 128, bn: int = 128,
                  bk: int = 256, interpret: bool | None = None) -> jax.Array:
    """Code-space LUNA GEMM with automatic padding.  int8 codes -> int32."""
    if interpret is None:
        interpret = _interpret_default()
    m, k = y_codes.shape
    n = w_codes.shape[1]
    bm_, bn_, bk_ = (min(bm, _ceil_mult(m)), min(bn, _ceil_mult(n)),
                     min(bk, _ceil_mult(k)))
    yp = _pad_to(y_codes.astype(jnp.int8), (bm_, bk_))
    wp = _pad_to(w_codes.astype(jnp.int8), (bk_, bn_))
    # NB zero padding is exact for every mode: zero codes contribute zero to
    # all digit planes and to colsum(W).
    out = luna_mm(yp, wp, mode=mode, bm=bm_, bn=bn_, bk=bk_,
                  interpret=interpret)
    return out[:m, :n]


def _ceil_mult(d: int, base: int = 8) -> int:
    """Largest power-of-two block <= d (>=8) so tiny shapes still work."""
    b = base
    while b * 2 <= d:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnames=("mode", "bits", "interpret"))
def luna_matmul_f32_kernel(x: jax.Array, w: jax.Array, *, mode: str = "opt_dc",
                           bits: int = 4,
                           interpret: bool | None = None) -> jax.Array:
    """Float GEMM through the integer kernel (dynamic PTQ, zero-point algebra).

    Mirrors ``repro.core.quant.luna_matmul_f32`` but runs the contraction in
    the Pallas kernel.  bits is fixed at 4 (the kernel's digit planes).
    """
    assert bits == 4, "the Pallas kernel implements the paper's 4b datapath"
    x_qp = calibrate(x, bits, axis=None)
    w_qp = calibrate(w, bits, axis=-1)
    qx = quantize(x, x_qp)
    qw = quantize(w, w_qp)
    lead = x.shape[:-1]
    k = x.shape[-1]
    acc = luna_mm_codes(qx.reshape(-1, k), qw, mode=mode,
                        interpret=interpret).astype(jnp.float32)
    acc = acc.reshape(*lead, w.shape[-1])
    colsum_qw = jnp.sum(qw, axis=0).astype(jnp.float32)
    rowsum_qx = jnp.sum(qx, axis=-1, keepdims=True).astype(jnp.float32)
    zx, zw = x_qp.zero_point, w_qp.zero_point
    corrected = acc - zx * colsum_qw - rowsum_qx * zw + k * zx * zw
    return (x_qp.scale * w_qp.scale) * corrected
