"""Pure-jnp oracle for the SSD chunk-scan kernel (naive O(S^2) recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array, initial_state: jax.Array | None = None,
            mask: jax.Array | None = None):
    """Sequential state-space recurrence, one token at a time.

    x: (BH, S, P); dt: (BH, S); a: (BH,); b/c: (BH, S, N).
    y_t = C_t^T S_t;  S_t = exp(dt_t a) S_{t-1} + dt_t B_t x_t^T.
    ``initial_state``: optional (BH, N, P) carried state (zeros when None);
    ``mask``: optional (BH, S) validity mask — invalid positions leave the
    state untouched (dt zeroed).
    Returns (y (BH,S,P), final_state (BH,N,P)).
    """
    bh, s, p = x.shape
    n = b.shape[-1]
    if mask is not None:
        dt = jnp.where(mask, dt, 0.0)
    if initial_state is None:
        initial_state = jnp.zeros((bh, n, p), jnp.float32)

    def per_stream(xs, dts, aa, bs, cs, init):
        def step(state, inp):
            x_t, dt_t, b_t, c_t = inp
            decay = jnp.exp(dt_t * aa)
            state = decay * state + dt_t * b_t[:, None] * x_t[None, :]
            y_t = c_t @ state                       # (P,)
            return state, y_t

        final, ys = jax.lax.scan(step, init, (xs, dts, bs, cs))
        return ys, final

    return jax.vmap(per_stream)(x.astype(jnp.float32), dt.astype(jnp.float32),
                                a.astype(jnp.float32), b.astype(jnp.float32),
                                c.astype(jnp.float32),
                                initial_state.astype(jnp.float32))
