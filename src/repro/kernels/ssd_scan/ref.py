"""Pure-jnp oracle for the SSD chunk-scan kernel (naive O(S^2) recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array):
    """Sequential state-space recurrence, one token at a time.

    x: (BH, S, P); dt: (BH, S); a: (BH,); b/c: (BH, S, N).
    y_t = C_t^T S_t;  S_t = exp(dt_t a) S_{t-1} + dt_t B_t x_t^T.
    Returns (y (BH,S,P), final_state (BH,N,P)).
    """
    bh, s, p = x.shape
    n = b.shape[-1]

    def per_stream(xs, dts, aa, bs, cs):
        def step(state, inp):
            x_t, dt_t, b_t, c_t = inp
            decay = jnp.exp(dt_t * aa)
            state = decay * state + dt_t * b_t[:, None] * x_t[None, :]
            y_t = c_t @ state                       # (P,)
            return state, y_t

        init = jnp.zeros((n, p), jnp.float32)
        final, ys = jax.lax.scan(step, init, (xs, dts, bs, cs))
        return ys, final

    return jax.vmap(per_stream)(x.astype(jnp.float32), dt.astype(jnp.float32),
                                a.astype(jnp.float32), b.astype(jnp.float32),
                                c.astype(jnp.float32))
