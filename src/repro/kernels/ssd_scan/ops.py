"""Model-facing wrapper: (B, S, H, P) tensors -> flattened head-streams."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_kernel(x, dt, a, b, c, *, chunk: int = 128,
                       interpret: bool = True, initial_state=None,
                       mask=None):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,G,N) with G|H.

    ``initial_state``: optional (B,H,P,N) carried state to continue from;
    ``mask``: optional (B,S) validity mask (pad columns are inert).
    Returns (y (B,S,H,P) f32, final_state (B,H,P,N)) matching
    ``repro.models.ssm._ssd_chunked``.
    """
    bb, s, h, p = x.shape
    g = b.shape[2]
    hg = h // g
    n = b.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(bb * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bb * h, s)
    bh_b = jnp.repeat(b, hg, axis=2).transpose(0, 2, 1, 3).reshape(
        bb * h, s, n)
    ch_c = jnp.repeat(c, hg, axis=2).transpose(0, 2, 1, 3).reshape(
        bb * h, s, n)
    af = jnp.tile(a, bb)
    s0 = None
    if initial_state is not None:                        # (B,H,P,N)->(BH,N,P)
        s0 = initial_state.transpose(0, 1, 3, 2).reshape(bb * h, n, p)
    mf = None
    if mask is not None:                                 # (B,S)->(BH,S)
        mf = jnp.broadcast_to(mask[:, None, :], (bb, h, s)).reshape(
            bb * h, s)
    y, fs = ssd_scan(xf, dtf, af, bh_b, ch_c, chunk=chunk,
                     interpret=interpret, initial_state=s0, mask=mf)
    y = y.reshape(bb, h, s, p).transpose(0, 2, 1, 3)
    fs = fs.reshape(bb, h, n, p).transpose(0, 1, 3, 2)   # (B,H,P,N)
    return y, fs
