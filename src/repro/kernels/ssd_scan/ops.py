"""Model-facing wrapper: (B, S, H, P) tensors -> flattened head-streams."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_kernel(x, dt, a, b, c, *, chunk: int = 128,
                       interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,G,N) with G|H.

    Returns (y (B,S,H,P) f32, final_state (B,H,P,N)) matching
    ``repro.models.ssm._ssd_chunked``.
    """
    bb, s, h, p = x.shape
    g = b.shape[2]
    hg = h // g
    n = b.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(bb * h, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bb * h, s)
    bh_b = jnp.repeat(b, hg, axis=2).transpose(0, 2, 1, 3).reshape(
        bb * h, s, n)
    ch_c = jnp.repeat(c, hg, axis=2).transpose(0, 2, 1, 3).reshape(
        bb * h, s, n)
    af = jnp.tile(a, bb)
    y, fs = ssd_scan(xf, dtf, af, bh_b, ch_c, chunk=chunk,
                     interpret=interpret)
    y = y.reshape(bb, h, s, p).transpose(0, 2, 1, 3)
    fs = fs.reshape(bb, h, n, p).transpose(0, 1, 3, 2)   # (B,H,P,N)
    return y, fs
