"""Pallas TPU kernel: Mamba2 SSD chunk scan (single head-stream per grid row).

Grid: ``(B*H, n_chunks)`` with the chunk dim innermost — the (P, N) state
lives in VMEM scratch and persists across the sequential chunk steps (the
same pattern as a matmul accumulator).  Per chunk the kernel does the three
SSD pieces entirely in VMEM:

  intra:   Y  = (C B^T ⊙ L) (x·dt)        two (Q,Q)x(Q,·) MXU matmuls
  inter:   Y += seg_start · (C S_prev)     (Q,N)x(N,P)
  state:   S  = decay·S_prev + (seg_end·B)^T (x·dt)   (N,Q)x(Q,P)

Q defaults to 128 (MXU-aligned); the (Q,Q) decay mask is built with iota.
This turns the per-layer SSD from ~7 jnp einsums with HBM round-trips into
one VMEM-resident kernel — the hot loop of mamba2-1.3b / zamba2-1.2b.

The scan is RESUMABLE: an optional (BH, N, P) ``initial_state`` seeds the
VMEM state at chunk 0 (instead of zeros) and the continued final state is
returned, and an optional (BH, S) validity ``mask`` makes right-padded
positions inert — together these let chunked/bucketed prefill feed a prompt
in pieces with exact state carry (see ``serve.engine``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, s0_ref, y_ref, fs_ref,
                state_ref, *, nc: int, q: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, 1)
    bmat = b_ref[0].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)       # (Q, N)
    a = a_ref[0, 0]                           # scalar A (negative)

    da_cum = jnp.cumsum(dt[:, 0] * a)[:, None]          # (Q, 1)
    seg_start = jnp.exp(da_cum)                         # (Q, 1)
    seg_end = jnp.exp(da_cum[-1:] - da_cum)             # (Q, 1)
    chunk_decay = jnp.exp(da_cum[-1, 0])
    xdt = x * dt                                        # (Q, P)

    # intra-chunk: L[i,j] = exp(da_cum[i]-da_cum[j]) for i >= j
    rel = da_cum - da_cum[:, 0][None, :]                # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.exp(jnp.where(rows >= cols, rel, -1e30))
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(cb * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk from carried state (N, P)
    state = state_ref[...]
    y += seg_start * jax.lax.dot_general(
        cmat, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update
    state = chunk_decay * state + jax.lax.dot_general(
        bmat * seg_end, xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)             # (N, P)
    state_ref[...] = state
    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(ic == nc - 1)
    def _final():
        fs_ref[0] = state.astype(fs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool = False, initial_state=None, mask=None):
    """SSD over flattened head-streams.

    x: (BH, S, P); dt: (BH, S); a: (BH,) negative decay rates;
    b/c: (BH, S, N).  ``initial_state``: optional (BH, N, P) carried state
    to continue from (zeros when None); ``mask``: optional (BH, S) validity
    mask — invalid positions are inert (dt is zeroed: the state freezes
    through them), so right-padded streams carry exactly their real tokens.
    Returns (y (BH, S, P) f32, final_state (BH, N, P)).
    """
    bh, s, p = x.shape
    n = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    if mask is not None:
        dt = jnp.where(mask, dt, 0.0)
    if initial_state is None:
        initial_state = jnp.zeros((bh, n, p), jnp.float32)

    y, fs = pl.pallas_call(
        functools.partial(_ssd_kernel, nc=nc, q=chunk),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, 1), lambda i, ic: (i, 0)),
            pl.BlockSpec((1, n, p), lambda i, ic: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda i, ic: (i, ic, 0)),
            pl.BlockSpec((1, n, p), lambda i, ic: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], b, c, a[:, None],
      initial_state.astype(jnp.float32))
    return y, fs
