"""Pallas TPU kernel: blockwise online-softmax (flash) attention, forward.

Used for 32k prefill where materializing the (S, S) score matrix is not an
option.  Grid: (batch*heads, S/bq, S/bkv) with the KV dimension innermost;
running max/denominator/accumulator live in VMEM scratch (the standard TPU
flash pipeline).  GQA is handled without materializing repeated KV heads:
the KV BlockSpec index map folds the query head onto its KV group.

VMEM working set per step (bq=256, bkv=512, d=128, f32):
q 128 KiB + k/v 512 KiB + acc 128 KiB + stats ~2 KiB — well under 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, bq: int, bkv: int, nkv: int):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _body():
        q = q_ref[0].astype(jnp.float32)                   # (bq, d)
        k = k_ref[0].astype(jnp.float32)                   # (bkv, d)
        v = v_ref[0].astype(jnp.float32)                   # (bkv, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= sm_scale                                      # (bq, bkv)
        if causal:
            rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            cols = ikv * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                             # (bq, bkv)
        corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # Skip fully-masked blocks (upper triangle).
        pl.when(ikv * bkv <= iq * bq + bq - 1)(_body)
    else:
        _body()

    @pl.when(ikv == nkv - 1)
    def _flush():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "causal", "bq",
                                             "bkv", "num_q_heads",
                                             "num_kv_heads", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    sm_scale: float, causal: bool = True,
                    num_q_heads: int, num_kv_heads: int,
                    bq: int = 256, bkv: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (B*H, S, D); k/v: (B*Hkv, S, D).  Returns (B*H, S, D).

    The KV index map folds each query head onto its GQA group, so KV is
    never materialized per-query-head.
    """
    bh, s, d = q.shape
    assert s % bq == 0 and s % bkv == 0, (s, bq, bkv)
    group = num_q_heads // num_kv_heads
    nkv = s // bkv

    def kv_index(i, iq, ikv):
        b = i // num_q_heads
        h = i % num_q_heads
        return (b * num_kv_heads + h // group, ikv, 0)

    return pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=sm_scale, causal=causal,
                          bq=bq, bkv=bkv, nkv=nkv),
        grid=(bh, s // bq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda i, iq, ikv: (i, iq, 0)),
            pl.BlockSpec((1, bkv, d), kv_index),
            pl.BlockSpec((1, bkv, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda i, iq, ikv: (i, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
