"""Pure-jnp oracle for flash attention (materializes the score matrix)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  sm_scale: float, causal: bool = True,
                  num_q_heads: int = 1, num_kv_heads: int = 1) -> jax.Array:
    """q: (B*H, S, D); k/v: (B*Hkv, S, D)."""
    bh, s, d = q.shape
    b = bh // num_q_heads
    group = num_q_heads // num_kv_heads
    qq = q.reshape(b, num_kv_heads, group, s, d).astype(jnp.float32)
    kk = k.reshape(b, num_kv_heads, 1, s, d).astype(jnp.float32)
    vv = v.reshape(b, num_kv_heads, 1, s, d).astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhgkd->bhgqk", qq, jnp.broadcast_to(kk, qq.shape))
    scores = scores * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhgkd->bhgqd", p, jnp.broadcast_to(vv, qq.shape))
    return out.reshape(bh, s, d).astype(q.dtype)
