"""Public attention entry point: picks flash kernel vs jnp by context."""
from __future__ import annotations


import jax

from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def mha(q: jax.Array, k: jax.Array, v: jax.Array, *, sm_scale: float,
        causal: bool = True, use_flash: bool = False,
        interpret: bool | None = None) -> jax.Array:
    """Multi-head attention with GQA.

    q: (B, S, H, D); k/v: (B, S, Hkv, D) -> (B, S, H, D).
    ``use_flash`` routes through the Pallas kernel (TPU target; interpret on
    CPU).  The jnp path is differentiable and used for training.
    """
    b, s, h, d = q.shape
    hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    if use_flash:
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        bq = min(256, s)
        bkv = min(512, s)
        out = flash_attention(qf, kf, vf, sm_scale=sm_scale, causal=causal,
                              num_q_heads=h, num_kv_heads=hkv, bq=bq,
                              bkv=bkv, interpret=interpret)
    else:
        out = attention_ref(qf, kf, vf, sm_scale=sm_scale, causal=causal,
                            num_q_heads=h, num_kv_heads=hkv)
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
