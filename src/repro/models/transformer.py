"""Decoder-only transformer LM (dense / MoE / MLA), scan-over-layers.

Design notes for scale:
  * layers are stacked (leading L axis) and executed with ``lax.scan`` —
    compile time and HLO size are depth-independent (95-layer deepseek-67b
    compiles as fast as 2 layers);
  * training wraps the block in ``jax.checkpoint`` (full remat policy) so
    the 4k x 256 train cells fit;
  * the LM loss is computed in sequence chunks so (S, vocab) logits are
    never materialized (minitron's 256k vocab would be 67 GB/device);
  * KV caches are stacked per layer and threaded through the scan as xs/ys.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.attention import KVCache, init_gqa, init_mla
from repro.models.common import (CacheSpec, dense_init, embed_init,
                                 gather_last, remat_policy_of, rms_norm,
                                 token_positions)
from repro.models.mlp import init_mlp, mlp


def _block_init(key, cfg, *, use_moe: bool, d_ff: int | None = None):
    ks = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": init_mla(ks[0], cfg) if cfg.mla else init_gqa(ks[0], cfg),
    }
    if use_moe:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, d_ff=d_ff)
    return p


def _block_apply(p, x, cfg, *, positions, cache, cache_index, use_moe: bool,
                 block_tables=None, n_valid=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        a, new_cache = attn_mod.mla_attention(
            p["attn"], h, cfg, positions=positions, cache=cache,
            cache_index=cache_index, block_table=block_tables,
            n_valid=n_valid)
    else:
        a, new_cache = attn_mod.gqa_attention(
            p["attn"], h, cfg, positions=positions, cache=cache,
            cache_index=cache_index, block_table=block_tables,
            n_valid=n_valid)
    x = x + a
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if use_moe:
        f, aux = moe_mod.moe_ffn(p["moe"], h, cfg,
                                 window=n_valid is not None)
    else:
        f = mlp(p["mlp"], h, cfg)
    return x + f, new_cache, aux


class TransformerLM:
    """Generic decoder-only LM covering dense, MoE and MLA families."""

    def __init__(self, cfg):
        self.cfg = cfg

    # ---------------- params ----------------
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        k_e, k_h, k_0, k_L = jax.random.split(key, 4)
        moe = cfg.moe
        n_dense = moe.first_dense if moe else 0
        n_scan = cfg.num_layers - n_dense
        params: dict[str, Any] = {
            "embed": embed_init(k_e, cfg.vocab_size, cfg.d_model, dt),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_h, cfg.d_model, cfg.vocab_size, dt)
        if n_dense:
            dense_ff = moe.dense_ff or cfg.d_ff
            params["dense_blocks"] = [
                _block_init(k, cfg, use_moe=False, d_ff=dense_ff)
                for k in jax.random.split(k_0, n_dense)]
        params["blocks"] = jax.vmap(
            lambda k: _block_init(k, cfg, use_moe=moe is not None)
        )(jax.random.split(k_L, n_scan))
        return params

    # ---------------- forward ----------------
    def _scan_blocks(self, params, x, *, positions, caches, cache_index,
                     training: bool, block_tables=None, n_valid=None):
        cfg = self.cfg
        use_moe = cfg.moe is not None
        from repro.parallel.act_sharding import shard_hidden

        def body(carry, xs):
            h, aux = carry
            p_i, cache_i = xs
            h = shard_hidden(h)
            h2, new_cache, aux_i = _block_apply(
                p_i, h, cfg, positions=positions, cache=cache_i,
                cache_index=cache_index, use_moe=use_moe,
                block_tables=block_tables, n_valid=n_valid)
            return (shard_hidden(h2), aux + aux_i), new_cache

        if training and cfg.remat:
            body = jax.checkpoint(
                body, policy=remat_policy_of(cfg))

        if not cfg.scan_layers:
            # accounting/probe mode: python loop (exact cost_analysis totals)
            aux = jnp.zeros((), jnp.float32)
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            new_caches = []
            carry = (x, aux)
            for i in range(n):
                p_i = jax.tree.map(lambda a: a[i], params["blocks"])
                c_i = (jax.tree.map(lambda a: a[i], caches)
                       if caches is not None else None)
                carry, nc = body(carry, (p_i, c_i))
                new_caches.append(nc)
            x, aux = carry
            if caches is not None:
                new_caches = jax.tree.map(lambda *xs: jnp.stack(xs, 0),
                                          *new_caches)
            else:
                new_caches = None
            return x, aux, new_caches

        (x, aux), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["blocks"], caches))
        return x, aux, new_caches

    def forward(self, params, tokens=None, *, embeds=None, caches=None,
                cache_index=0, training: bool = False, block_tables=None,
                n_valid=None):
        """Returns (hidden (B,S,D), aux, new_caches)."""
        cfg = self.cfg
        if embeds is None:
            embeds = params["embed"][tokens]
        x = embeds
        b, s, _ = x.shape
        positions = token_positions(s, cache_index)
        moe = cfg.moe
        n_dense = moe.first_dense if moe else 0
        dense_caches, scan_caches = None, None
        if caches is not None:
            dense_caches, scan_caches = caches
        new_dense_caches = []
        for i in range(n_dense):
            c = dense_caches[i] if dense_caches is not None else None
            x, nc, _ = _block_apply(
                params["dense_blocks"][i], x, cfg, positions=positions,
                cache=c, cache_index=cache_index, use_moe=False,
                block_tables=block_tables, n_valid=n_valid)
            new_dense_caches.append(nc)
        x, aux, new_scan = self._scan_blocks(
            params, x, positions=positions,
            caches=scan_caches if scan_caches is not None else _none_caches(
                cfg.num_layers - n_dense),
            cache_index=cache_index, training=training,
            block_tables=block_tables, n_valid=n_valid)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        new_caches = (new_dense_caches, new_scan) if caches is not None else None
        return x, aux, new_caches

    def logits(self, params, hidden):
        cfg = self.cfg
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        return quant_matmul(hidden, head, None)

    # ---------------- training ----------------
    def loss(self, params, batch):
        """batch: tokens (B, S), labels (B, S)[, embeds for VLM]."""
        cfg = self.cfg
        hidden, aux, _ = self.forward(
            params, batch.get("tokens"), embeds=batch.get("embeds"),
            training=True)
        head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        xent = chunked_xent(hidden, head, labels, mask,
                            unroll=not cfg.scan_layers)
        return xent + aux, {"xent": xent, "aux": aux}

    # ---------------- serving ----------------
    def init_cache(self, batch: int, s_max: int, *,
                   spec: CacheSpec | None = None) -> tuple:
        """Dense slab caches (B, s_max, ...) by default.  With a paged
        ``spec``, every KV leaf becomes a paged pool
        (num_blocks, block_size, ...) shared by all slots and indexed via a
        per-row block table (``batch``/``s_max`` then only size the layout,
        not the leaves)."""
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        moe = cfg.moe
        n_dense = moe.first_dense if moe else 0
        n_scan = cfg.num_layers - n_dense
        if spec is not None and spec.paged:
            lead = (spec.num_blocks, spec.block_size)
        else:
            lead = (batch, s_max)

        def one():
            if cfg.mla:
                m = cfg.mla
                tails = ((m.kv_lora_rank,), (m.qk_rope_dim,))
            else:
                hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
                tails = ((hkv, dh), (hkv, dh))
            return KVCache(jnp.zeros(lead + tails[0], dt),
                           jnp.zeros(lead + tails[1], dt))

        dense_caches = [one() for _ in range(n_dense)]
        one_c = one()
        scan_caches = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_scan,) + a.shape).copy(),
            one_c)
        return (dense_caches, scan_caches)

    def prefill(self, params, tokens, caches, *, embeds=None, last_pos=None,
                cache_index=0):
        """``last_pos``: optional (B,) per-row index of the last REAL token
        (right-padded batched prefill); default = the final column.
        ``cache_index``: scalar write offset — chunked prefill feeds the
        prompt in pieces, each continuing at the previous chunk's end."""
        hidden, _, new_caches = self.forward(
            params, tokens, embeds=embeds, caches=caches,
            cache_index=cache_index)
        last = (hidden[:, -1:] if last_pos is None
                else gather_last(hidden, last_pos))
        logits = self.logits(params, last)
        return logits, new_caches

    def decode_step(self, params, token, state, index, *, tables=None):
        """token: (B, 1) int32; index: scalar int32 position shared by all
        rows, or a (B,) int32 array of per-row positions (mixed-depth
        continuous batching).  ``tables``: (B, nblk) int32 block tables
        when ``state`` holds paged pools (see ``init_cache``).

        ``params`` may be the engine's frozen 4-bit decode tree
        (``EngineConfig(quant=...)``): attention/MLP projection leaves are
        then ``QuantizedWeight`` containers that ``quant_matmul`` routes
        through the D&C LUT gemm — scan-stacked leaves slice per layer
        like any float leaf (registered pytree with a leading L axis)."""
        hidden, _, new_caches = self.forward(
            params, token, caches=state, cache_index=index,
            block_tables=tables)
        return self.logits(params, hidden), new_caches

    def decode_window(self, params, tokens, state, index, *, tables=None,
                      n_valid=None, last_pos=None):
        """Speculative verify: score a (B, W) window of already-chosen
        tokens in ONE batched forward.  ``index``: (B,) per-row positions
        of window column 0; ``n_valid``: (B,) real tokens per row (the
        rest write nowhere and are masked out of attention — inactive rows
        pass 0 and touch nothing).  ``last_pos`` is accepted for signature
        uniformity with the recurrent families and ignored: KV beyond a
        row's rewound pointer is dead weight the next writes overwrite, so
        the verify-pass cache IS the committed cache at any accept length.

        Returns (logits (B, W, V), new_caches) — logits[:, i] scores the
        token AFTER window column i."""
        del last_pos
        hidden, _, new_caches = self.forward(
            params, tokens, caches=state, cache_index=index,
            block_tables=tables, n_valid=n_valid)
        return self.logits(params, hidden), new_caches


def _none_caches(n: int):
    return None


def chunked_xent(hidden, head, labels, mask=None, chunk: int = 256,
                 unroll: bool = False):
    """Sequence-chunked cross entropy: never materializes (S, V) logits."""
    b, s, d = hidden.shape
    if s <= chunk:
        logits = (hidden @ head).astype(jnp.float32)
        return _xent(logits, labels, mask)
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)

    def piece(h, lab, m):
        logits = (h @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        return ((logz - gold) * m).sum(), m.sum()

    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)
    if unroll:
        tot = cnt = 0.0
        for i in range(nc):
            sl = slice(i * chunk, (i + 1) * chunk)
            t, c = piece(hidden[:, sl], labels[:, sl], mask[:, sl])
            tot, cnt = tot + t, cnt + c
        return tot / jnp.maximum(cnt, 1.0)

    hs = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    ms = mask.reshape(b, nc, chunk).transpose(1, 0, 2)

    def step(acc, xs):
        t, c = piece(*xs)
        return (acc[0] + t, acc[1] + c), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.zeros(()), jnp.zeros(())),
                                 (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def _xent(logits, labels, mask=None):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
