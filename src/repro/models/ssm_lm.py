"""Mamba2 (attention-free) LM: scan over SSD blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models.common import (dense_init, embed_init, gather_last,
                                 reject_paged_spec, remat_policy_of,
                                 rms_norm)
from repro.models.ssm import (SSMCache, init_mamba2, mamba2_block,
                              snapshot_row, ssm_cache_shape)
from repro.models.transformer import chunked_xent


class SSMLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 3)
        return {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "lm_head": dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt),
            "blocks": jax.vmap(lambda k: {
                "ln": jnp.ones((cfg.d_model,), jnp.float32),
                "m": init_mamba2(k, cfg)})(
                    jax.random.split(ks[2], cfg.num_layers)),
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        }

    def forward(self, params, tokens, *, caches=None, cache_index=0,
                training=False, last_pos=None):
        cfg = self.cfg
        from repro.parallel.act_sharding import shard_hidden
        x = params["embed"][tokens]

        def body(h, xs):
            p_i, cache_i = xs
            h = shard_hidden(h)
            y, new_cache = mamba2_block(
                p_i["m"], rms_norm(h, p_i["ln"], cfg.norm_eps), cfg,
                cache=cache_i, last_pos=last_pos)
            return shard_hidden(h + y), new_cache

        if training and cfg.remat:
            body = jax.checkpoint(
                body, policy=remat_policy_of(cfg))
        if not cfg.scan_layers:
            n = jax.tree.leaves(params["blocks"])[0].shape[0]
            ncs = []
            for i in range(n):
                p_i = jax.tree.map(lambda a: a[i], params["blocks"])
                c_i = (jax.tree.map(lambda a: a[i], caches)
                       if caches is not None else None)
                x, nc = body(x, (p_i, c_i))
                ncs.append(nc)
            new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ncs)
                          if caches is not None else None)
            return rms_norm(x, params["ln_f"], cfg.norm_eps), new_caches
        x, new_caches = jax.lax.scan(body, x, (params["blocks"], caches))
        return rms_norm(x, params["ln_f"], cfg.norm_eps), new_caches

    def loss(self, params, batch):
        hidden, _ = self.forward(params, batch["tokens"], training=True)
        xent = chunked_xent(hidden, params["lm_head"], batch["labels"],
                            batch.get("loss_mask"),
                            unroll=not self.cfg.scan_layers)
        return xent, {"xent": xent}

    def init_cache(self, batch: int, s_max: int, *, spec=None):
        """Recurrent state is O(1) per slot — paging buys nothing, so a
        paged spec is rejected and the cache stays dense (B, ...)."""
        reject_paged_spec(spec, "ssm", "recurrent state is O(1) per slot; "
                          "paged KV pools apply to attention slabs")
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        conv_s, state_s = ssm_cache_shape(cfg, batch)
        return SSMCache(
            jnp.zeros((cfg.num_layers,) + conv_s, dt),
            jnp.zeros((cfg.num_layers,) + state_s, jnp.float32))

    def state_snapshot(self, caches, row: int = 0):
        """Prefix-cache export: the whole cache IS the recurrent state —
        one (conv, ssd) row pair at ``row``, O(1) in prefix length."""
        return snapshot_row(caches, row)

    def seed_from_snapshot(self, staging, snap):
        """Warm admission: a 1-row staging cache seeded from a snapshot is
        the snapshot itself (position-free recurrence, nothing else to
        restore)."""
        del staging
        return snap

    def prefill(self, params, tokens, caches, *, last_pos=None,
                cache_index=0):
        """``last_pos``: (B,) index of each row's last REAL token — pad
        columns of a right-padded length bucket are masked out of the
        recurrent state (masked SSD scan + per-row conv-state gather).
        ``cache_index`` > 0 means a chunked-prefill continuation: the SSM
        recurrence is position-free, so the offset itself is unused — the
        carried (conv, state) in ``caches`` IS the continuation point and
        the scan resumes from it exactly."""
        hidden, new_caches = self.forward(params, tokens, caches=caches,
                                          last_pos=last_pos)
        last = (hidden[:, -1:] if last_pos is None
                else gather_last(hidden, last_pos))
        logits = quant_matmul(last, params["lm_head"], None)
        return logits, new_caches

    def decode_step(self, params, token, state, index, *, tables=None):
        """``index``: scalar or (B,) — unused by the position-free SSM
        recurrence, accepted for the uniform engine-facing signature.
        ``tables`` must be None (dense recurrent state)."""
        assert tables is None, "ssm caches are dense (no block table)"
        hidden, new_caches = self.forward(params, token, caches=state,
                                          cache_index=index)
        logits = quant_matmul(hidden, params["lm_head"], None)
        return logits, new_caches

    def decode_window(self, params, tokens, state, index, *, tables=None,
                      n_valid=None, last_pos=None):
        """Speculative verify/commit over a (B, W) token window via the
        masked SSD scan continuing from the carried recurrent state.

        The recurrence cannot rewind, so ``last_pos`` (B,) bounds what
        ENTERS the state: positions beyond it are dt-masked (state frozen,
        contribution zero) while their causal outputs still score the
        window.  Verify passes ``last_pos = n_valid - 1`` (score all
        drafts); a partial-accept commit re-runs from the pre-verify tree
        with ``last_pos = accepts`` so exactly the accepted prefix enters
        the state.  A row with ``last_pos = -1`` is fully masked — its
        conv window and SSD state pass through unchanged.  ``n_valid`` is
        accepted for signature uniformity (attention families use it) and
        folded into the default ``last_pos`` when one isn't given."""
        assert tables is None, "ssm caches are dense (no block table)"
        if last_pos is None and n_valid is not None:
            last_pos = jnp.asarray(n_valid, jnp.int32) - 1
        hidden, new_caches = self.forward(params, tokens, caches=state,
                                          cache_index=index,
                                          last_pos=last_pos)
        logits = quant_matmul(hidden, params["lm_head"], None)
        return logits, new_caches
