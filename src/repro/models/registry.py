"""Architecture registry: ``--arch <id>`` -> (config, model, input specs)."""
from __future__ import annotations

import importlib
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

ARCH_MODULES = {
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "minitron-4b": "repro.configs.minitron_4b",
    "yi-9b": "repro.configs.yi_9b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "whisper-base": "repro.configs.whisper_base",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "mamba2-1.3b": "repro.configs.mamba2_1p3b",
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "luna-mlp": "repro.configs.luna_mlp",
}

ARCH_IDS = [a for a in ARCH_MODULES if a != "luna-mlp"]

# archs with sub-quadratic sequence mixing (run the long_500k cell)
SUBQUADRATIC = {"zamba2-1.2b", "mamba2-1.3b"}


def get_config(arch: str, **overrides) -> ModelConfig:
    cfg = importlib.import_module(ARCH_MODULES[arch]).CONFIG
    return replace(cfg, **overrides) if overrides else cfg


def get_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        from repro.models.transformer import TransformerLM
        return TransformerLM(cfg)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecLM
        return EncDecLM(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import HybridLM
        return HybridLM(cfg)
    if cfg.family == "ssm":
        from repro.models.ssm_lm import SSMLM
        return SSMLM(cfg)
    if cfg.family == "vlm":
        from repro.models.vlm import VLM
        return VLM(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def cell_supported(arch: str, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether this (arch x shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and arch not in SUBQUADRATIC:
        return False, ("SKIP: pure full-attention arch; 500k decode needs "
                       "sub-quadratic attention (DESIGN.md section 5)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeConfig, batch: int | None = None
                ) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``batch`` overrides the global batch (per-device slicing is done by the
    sharding layer, these are GLOBAL logical shapes).
    """
    b = batch or shape.global_batch
    s = shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    i32 = jnp.int32

    def sds(shp, dtype=i32):
        return jax.ShapeDtypeStruct(shp, dtype)

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {"frames": sds((b, cfg.encdec.enc_seq, cfg.d_model), dt),
                    "tokens": sds((b, s)), "labels": sds((b, s))}
        if cfg.family == "vlm":
            p = cfg.vlm.num_patches
            return {"patches": sds((b, p, cfg.d_model), dt),
                    "tokens": sds((b, s - p)), "labels": sds((b, s))}
        return {"tokens": sds((b, s)), "labels": sds((b, s))}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"frames": sds((b, cfg.encdec.enc_seq, cfg.d_model), dt),
                    "tokens": sds((b, s))}
        if cfg.family == "vlm":
            p = cfg.vlm.num_patches
            return {"patches": sds((b, p, cfg.d_model), dt),
                    "tokens": sds((b, s - p))}
        return {"tokens": sds((b, s))}

    # decode: one new token against an s-long cache
    return {"token": sds((b, 1)), "index": jax.ShapeDtypeStruct((), i32)}
