"""Mamba2 (SSD — state-space duality) block, chunked training + step decode.

Chunked SSD (Dao & Gu 2024): within chunks of size Q the output is a masked
quadratic form (attention-like, MXU friendly); across chunks a compact
(H, P, N) state is carried by a linear recurrence (lax.scan).  Decode is the
O(1)-per-token recurrence on (conv_state, ssm_state) — this is what makes the
``long_500k`` cell tractable for the SSM/hybrid archs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models.common import dense_init


class SSMCache(NamedTuple):
    conv: jax.Array   # (B, conv_dim-1, conv_channels)
    state: jax.Array  # (B, H, P, N)


def snapshot_row(cache: SSMCache, row: int = 0) -> SSMCache:
    """One batch row of a layer-stacked (L, B, ...) recurrent cache,
    keepdim — the fixed-size dense state snapshot the prefix cache stores
    at a prompt boundary.  The SSD scan is state-continuing (it accepts an
    initial (B,H,P,N) state), so prefill seeded from this snapshot resumes
    exactly where the cached prefix left off."""
    return SSMCache(cache.conv[:, row:row + 1], cache.state[:, row:row + 1])


def _dims(cfg):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    nheads = d_inner // sc.head_dim
    conv_ch = d_inner + 2 * sc.num_groups * sc.state_dim
    return d_inner, nheads, conv_ch


def init_mamba2(key, cfg):
    sc = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_ch = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 5)
    in_dim = 2 * d_inner + 2 * sc.num_groups * sc.state_dim + nheads
    return {
        "w_in": dense_init(ks[0], d, in_dim, dt),
        "conv_w": (jax.random.normal(ks[1], (sc.conv_dim, conv_ch)) * 0.2
                   ).astype(dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dt),
        "w_out": dense_init(ks[2], d_inner, d, dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None,
                 last_pos: jax.Array | None = None):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).

    Returns (y, new_state) where state holds the last K-1 inputs.
    ``last_pos``: optional (B,) index of each row's last REAL input
    (right-padded batched prefill) — the state window is then gathered at
    each row's own valid end, so pad columns never enter the carried state.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    if last_pos is None:
        new_state = xp[:, -(k - 1):]
    else:
        # row with valid length L: its state is the K-1 inputs before
        # position L, i.e. xp[L : L+K-1] (xp[i] = input at position i-(K-1))
        lengths = jnp.asarray(last_pos, jnp.int32) + 1
        idx = lengths[:, None] + jnp.arange(k - 1)[None, :]
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return jax.nn.silu(y + b[None, None]), new_state


def _ssd_chunked(x, dt, A, B, C, chunk: int, unroll: bool = False,
                 initial_state=None, mask=None):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative); B/C: (B,S,G,N).

    ``initial_state``: optional (B,H,P,N) carried state — the scan CONTINUES
    from it (chunked prefill) instead of restarting from zeros.
    ``mask``: optional (B,S) validity mask — invalid positions contribute
    nothing to the state or to later valid outputs (dt is zeroed there:
    decay exp(0*A) = 1 freezes the state and x*dt vanishes), so
    right-padded rows carry exactly their real tokens' state.

    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    if mask is not None:
        dt = jnp.where(mask[..., None], dt, 0.0)
    pad = -s % chunk
    if pad:          # internal right-pad to the chunk grid; dt=0 is inert
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    hg = h // g                                           # heads per group

    # reshape to chunks
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(state, inp):
        """One chunk: intra (quadratic) + inter (from carried state)."""
        xq, dtq, Bq, Cq = inp            # (B,Q,H,P),(B,Q,H),(B,Q,G,N)x2
        dA_cum = jnp.cumsum(dtq * A[None, None, :], axis=1)   # (B,Q,H)
        seg_start = jnp.exp(dA_cum)                           # decay 0..i
        seg_end = jnp.exp(dA_cum[:, -1:, :] - dA_cum)         # decay i..end
        chunk_decay = jnp.exp(dA_cum[:, -1, :])               # (B,H)
        xdt = xq * dtq[..., None]                             # (B,Q,H,P)
        Bh = jnp.repeat(Bq, hg, axis=2)                       # (B,Q,H,N)
        Ch = jnp.repeat(Cq, hg, axis=2)

        # intra-chunk: L[q,k] = exp(dA_cum[q]-dA_cum[k]) for q >= k
        # (mask BEFORE exp: exp at masked q<k positions overflows and
        #  0 * inf = NaN in the backward pass)
        rel = dA_cum[:, :, None, :] - dA_cum[:, None, :, :]   # (B,Q,Q,H)
        L = jnp.exp(jnp.where(causal[None, :, :, None], rel, -1e30))
        cb = jnp.einsum("bqgn,bkgn->bqkg", Cq, Bq)            # (B,Q,Q,G)
        cb = jnp.repeat(cb, hg, axis=-1)                      # (B,Q,Q,H)
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", cb * L, xdt)

        # inter-chunk from carried state
        y_inter = jnp.einsum("bqh,bqhn,bhpn->bqhp", seg_start, Ch, state)

        new_state = (state * chunk_decay[..., None, None]
                     + jnp.einsum("bqh,bqhn,bqhp->bhpn", seg_end, Bh, xdt))
        return new_state, y_intra + y_inter

    init = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
            else initial_state.astype(jnp.float32))
    if unroll:   # accounting mode: python loop (exact cost_analysis totals)
        state, ys = init, []
        for i in range(nc):
            state, y_i = chunk_step(
                state, (xc[:, i], dtc[:, i], Bc[:, i], Cc[:, i]))
            ys.append(y_i)
        return jnp.stack(ys, 1).reshape(b, sp, h, p)[:, :s], state
    xs_c = xc.transpose(1, 0, 2, 3, 4)                        # (NC,B,Q,H,P)
    dt_c = dtc.transpose(1, 0, 2, 3)
    B_s = Bc.transpose(1, 0, 2, 3, 4)
    C_s = Cc.transpose(1, 0, 2, 3, 4)
    final, ys = jax.lax.scan(chunk_step, init, (xs_c, dt_c, B_s, C_s))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, p)[:, :s]
    return y, final


def mamba2_block(params, x: jax.Array, cfg, cache: SSMCache | None = None,
                 last_pos: jax.Array | None = None):
    """x: (B, S, D) -> (y, new_cache).  S == 1 uses the decode recurrence.

    Prefill (S > 1) CONTINUES the carried (conv, state) from ``cache`` —
    fresh caches are zeros, so whole-prompt prefill is unchanged, and
    chunked prefill feeds the prompt in pieces with exact state carry.
    ``last_pos``: optional (B,) index of each row's last REAL token; pad
    columns beyond it are masked out of the recurrent state (right-padded
    length-bucketed prefill).
    """
    sc = cfg.ssm
    d_inner, nheads, conv_ch = _dims(cfg)
    b, s, _ = x.shape
    gn = sc.num_groups * sc.state_dim

    zxbcdt = quant_matmul(x, params["w_in"], cfg.quant, "mlp")
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None])    # (B,S,H)
    A = -jnp.exp(params["A_log"])                            # (H,) negative

    conv_state = cache.conv if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state,
                                 last_pos=last_pos if s > 1 else None)
    xs = xbc[..., :d_inner].reshape(b, s, nheads, sc.head_dim)
    B_ = xbc[..., d_inner:d_inner + gn].reshape(b, s, sc.num_groups,
                                                sc.state_dim)
    C_ = xbc[..., d_inner + gn:].reshape(b, s, sc.num_groups, sc.state_dim)

    if s == 1 and cache is not None:
        # --- O(1) decode step ---
        hg = nheads // sc.num_groups
        dA = jnp.exp(dt[:, 0] * A[None])                     # (B,H)
        Bh = jnp.repeat(B_[:, 0], hg, axis=1)                # (B,H,N)
        Ch = jnp.repeat(C_[:, 0], hg, axis=1)
        xdt = xs[:, 0] * dt[:, 0][..., None]                 # (B,H,P)
        new_state = (cache.state * dA[..., None, None]
                     + jnp.einsum("bhn,bhp->bhpn", Bh, xdt).astype(
                         cache.state.dtype))
        y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state.astype(jnp.float32))
        y = y[:, None]                                       # (B,1,H,P)
        final_state = new_state
    else:
        seq_mask = None
        if last_pos is not None:
            seq_mask = (jnp.arange(s)[None, :]
                        <= jnp.asarray(last_pos, jnp.int32)[:, None])
        y, final_state = _ssd_chunked(
            xs.astype(jnp.float32), dt, A, B_.astype(jnp.float32),
            C_.astype(jnp.float32), min(sc.chunk_size, s),
            unroll=not cfg.scan_layers,
            initial_state=(cache.state if cache is not None else None),
            mask=seq_mask)
        if cache is not None:
            final_state = final_state.astype(cache.state.dtype)

    y = y + params["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + cfg.norm_eps)
         * params["norm_w"].astype(jnp.float32)).astype(x.dtype)
    out = quant_matmul(y, params["w_out"], cfg.quant, "mlp")
    new_cache = None
    if cache is not None:
        new_cache = SSMCache(new_conv.astype(cache.conv.dtype), final_state)
    return out, new_cache


def ssm_cache_shape(cfg, batch: int):
    sc = cfg.ssm
    d_inner, nheads, conv_ch = _dims(cfg)
    return ((batch, sc.conv_dim - 1, conv_ch),
            (batch, nheads, sc.head_dim, sc.state_dim))
