"""Shared model building blocks (pure-functional, param-dict style)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CacheSpec:
    """Cache-layout request: THE uniform ``init_cache`` contract.

    Every family exposes ``init_cache(batch, s_max, *, spec=None)``.  With
    ``spec=None`` (or a spec without paging) the cache is a dense slab of
    per-slot (batch, s_max, ...) rows.  A paged spec turns every pageable
    KV leaf into a pool of ``num_blocks`` fixed ``block_size``-token blocks
    indexed via per-row block tables (families without pageable leaves —
    recurrent state, modality caches — must reject a paged spec rather
    than silently ignore it)."""
    block_size: int | None = None
    num_blocks: int | None = None

    def __post_init__(self):
        if (self.block_size is None) != (self.num_blocks is None):
            raise ValueError(
                "CacheSpec paging needs BOTH block_size and num_blocks "
                f"(got block_size={self.block_size}, "
                f"num_blocks={self.num_blocks})")

    @property
    def paged(self) -> bool:
        return self.block_size is not None


def reject_paged_spec(spec: CacheSpec | None, family: str, why: str) -> None:
    """Shared guard for families with nothing to page."""
    if spec is not None and spec.paged:
        raise ValueError(f"family {family!r} rejects a paged CacheSpec: "
                         f"{why}")


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def remat_policy_of(cfg):
    if getattr(cfg, "remat_policy", "nothing") == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)).astype(dt)


def token_positions(s: int, cache_index) -> jax.Array:
    """Absolute positions of ``s`` new tokens appended at ``cache_index``.

    ``cache_index`` is a scalar (shared depth: prefill / uniform decode) or a
    (B,) int32 array (continuous batching: each slab row at its own depth).
    Returns (1, S) or (B, S), broadcastable against (B, S) activations.
    """
    idx = cache_index
    if getattr(idx, "ndim", 0) == 1:
        return idx[:, None] + jnp.arange(s)[None, :]
    return jnp.arange(s)[None, :] + idx


def paged_gather(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Logical-order view of each row's paged cache.

    ``pool``: (num_blocks, block_size, ...); ``block_table``: (B, nblk)
    int32 physical block ids in logical order.  Returns
    (B, nblk * block_size, ...) — column ``j`` is logical token ``j`` of the
    row.  Unreserved table entries point at the garbage block; their columns
    sit beyond the row's ``kv_len`` and are masked by the caller.
    """
    g = pool[jnp.clip(block_table, 0, pool.shape[0] - 1)]
    return g.reshape((block_table.shape[0], -1) + pool.shape[2:])


def paged_write(pool: jax.Array, new: jax.Array, block_table: jax.Array,
                index: jax.Array) -> jax.Array:
    """Write one new token per row into a paged pool at its logical depth.

    ``new``: (B, 1, ...); ``index``: (B,) logical positions.  The physical
    target is ``block_table[row, index // block_size]`` at offset
    ``index % block_size``.  Rows the engine parks on the garbage block all
    write there (duplicate indices — nondeterministic winner, never read).
    """
    bs = pool.shape[1]
    idx = jnp.asarray(index, jnp.int32)
    rows = jnp.arange(new.shape[0])
    phys = block_table[rows, idx // bs]
    return pool.at[phys, idx % bs].set(new[:, 0].astype(pool.dtype))


def dense_write_window(cache: jax.Array, new: jax.Array, index: jax.Array,
                       n_valid: jax.Array | None = None) -> jax.Array:
    """Scatter an S-token window per row into a dense (B, S_max, ...) slab.

    ``new``: (B, S, ...); ``index``: (B,) per-row start positions — row
    ``b``'s token ``i`` lands at ``index[b] + i``.  ``n_valid``: optional
    (B,) count of REAL tokens per row; entries at or beyond it are routed
    to an out-of-bounds index and DROPPED (speculative verify windows mix
    rows with different draft counts — junk columns must write nowhere,
    not clamp onto committed positions).
    """
    b, s = new.shape[0], new.shape[1]
    idx = jnp.asarray(index, jnp.int32)[:, None] + jnp.arange(s)[None, :]
    if n_valid is not None:
        ok = jnp.arange(s)[None, :] < jnp.asarray(n_valid,
                                                  jnp.int32)[:, None]
        idx = jnp.where(ok, idx, cache.shape[1])
    rows = jnp.arange(b)[:, None]
    return cache.at[rows, idx].set(new.astype(cache.dtype), mode="drop")


def paged_write_window(pool: jax.Array, new: jax.Array,
                       block_table: jax.Array, index: jax.Array,
                       n_valid: jax.Array | None = None) -> jax.Array:
    """:func:`paged_write` generalized to an S-token window per row.

    ``new``: (B, S, ...); ``index``: (B,) per-row logical start positions.
    ``n_valid``: optional (B,) count of real tokens — invalid window
    entries get the out-of-bounds physical id ``num_blocks`` and are
    DROPPED by the scatter, so a row's junk columns can never collide
    with another row's committed KV (clamping would).
    """
    b, s = new.shape[0], new.shape[1]
    bs = pool.shape[1]
    idx = jnp.asarray(index, jnp.int32)[:, None] + jnp.arange(s)[None, :]
    col = jnp.clip(idx // bs, 0, block_table.shape[1] - 1)
    phys = jnp.take_along_axis(block_table, col, axis=1)        # (B, S)
    if n_valid is not None:
        ok = jnp.arange(s)[None, :] < jnp.asarray(n_valid,
                                                  jnp.int32)[:, None]
        phys = jnp.where(ok, phys, pool.shape[0])
    return pool.at[phys, idx % bs].set(new.astype(pool.dtype), mode="drop")


def gather_last(hidden: jax.Array, last_pos) -> jax.Array:
    """hidden: (B, S, D) -> (B, 1, D) at per-row ``last_pos`` (B,) (the last
    REAL token of each row in a right-padded prefill batch)."""
    idx = jnp.asarray(last_pos, jnp.int32).reshape(-1, 1, 1)
    return jnp.take_along_axis(hidden, idx, axis=1)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) or (..., S, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    if x.ndim == angles.ndim + 1:                      # head axis present
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2].astype(jnp.float32), x[..., 1::2].astype(jnp.float32)
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def causal_mask_bias(s_q: int, s_k: int, q_offset: jax.Array | int = 0
                     ) -> jax.Array:
    """(s_q, s_k) additive bias; q global position = q_offset + row."""
    rows = jnp.arange(s_q)[:, None] + q_offset
    cols = jnp.arange(s_k)[None, :]
    return jnp.where(rows >= cols, 0.0, -1e30).astype(jnp.float32)


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross-entropy; logits (..., V), labels (...) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
