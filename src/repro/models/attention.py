"""Attention modules: GQA (RoPE) and MLA (DeepSeek-V2), with KV caches.

All projections route through ``core.layers.quant_matmul`` so every
architecture can run under any LUNA quantization mode.

Tensor convention: activations (B, S, D); per-head tensors (B, S, H, Dh).
KV caches are preallocated (B, S_max, ...) and written at ``cache_index``
(static-shape decode, dry-run friendly).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models.common import (apply_rope, dense_init, dense_write_window,
                                 paged_gather, paged_write,
                                 paged_write_window)


class KVCache(NamedTuple):
    k: jax.Array   # (B, S_max, Hkv, Dh)  [GQA]  or c_kv (B, S_max, R) [MLA]
    v: jax.Array   # (B, S_max, Hkv, Dh)  [GQA]  or k_rope (B, S_max, dr) [MLA]


# ---------------------------------------------------------------------------
# Scaled dot-product attention with GQA broadcast, three impls
# ---------------------------------------------------------------------------

def _per_row(q_offset, kv_len) -> bool:
    """True when offsets are per-row (B,) arrays (mixed-depth batched decode)."""
    return any(v is not None and getattr(v, "ndim", 0) == 1
               for v in (q_offset, kv_len))


def _bias(sq: int, sk: int, q_offset, causal: bool, kv_len=None) -> jax.Array:
    """Additive mask bias.

    Scalar offsets -> (sq, sk), broadcast over batch and heads.  Per-row
    (B,)-shaped ``q_offset``/``kv_len`` (continuous batching: every slab row
    decodes at its own depth) -> (B, 1, sq, sk).
    """
    if _per_row(q_offset, kv_len):
        off = jnp.asarray(q_offset if q_offset is not None else 0)
        rows = jnp.arange(sq)[None, :, None] + off.reshape(-1, 1, 1)
        cols = jnp.arange(sk)[None, None, :]
        ok = jnp.ones((rows.shape[0], sq, sk), bool)
        if causal:
            ok &= rows >= cols
        if kv_len is not None:
            ok &= cols < jnp.asarray(kv_len).reshape(-1, 1, 1)
        return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[:, None]
    rows = jnp.arange(sq)[:, None] + (q_offset if q_offset is not None else 0)
    cols = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= rows >= cols
    if kv_len is not None:                      # mask unwritten cache slots
        ok &= cols < kv_len
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
         q_offset=0, kv_len=None, impl: str = "chunked",
         chunk: int = 512, unroll: bool = False,
         f32_operands: bool = True, fused_mask: bool = False,
         causal_skip: bool = False) -> jax.Array:
    """q: (B, Sq, H, Dh); k/v: (B, Sk, Hkv, Dh) -> (B, Sq, H, Dh).

    H-major throughout: KV heads are broadcast up to H *before* the score
    einsum so the head axis stays TP-shardable (an (hkv, group) split would
    make hkv=4 unshardable over a 16-way model axis and silently replicate
    every score tensor).  After head-sharding the broadcast costs nothing:
    each device holds only its H/model head slice.
    """
    from repro.parallel.act_sharding import shard_heads
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)

    if impl == "flash" and sq > 1 and kv_len is None:
        from repro.kernels.flash_attention.ops import mha
        return mha(q, k, v, sm_scale=float(1.0 / dh ** 0.5), causal=causal,
                   use_flash=True)

    if g > 1:
        k = jnp.repeat(k, g, axis=2)            # (B, Sk, H, Dh)
        v = jnp.repeat(v, g, axis=2)
    k = shard_heads(k)
    v = shard_heads(v)
    q = shard_heads(q)
    def _mask(s, sq_c, sk_c, off):
        if not fused_mask or _per_row(off, kv_len):
            # baseline: scale-mul then broadcast-bias add (also the only
            # path that supports per-row offsets)
            return s * scale + _bias(sq_c, sk_c, off, causal, kv_len)
        # fused scale+mask: one where() instead of mul + broadcast-bias-add
        rows = jnp.arange(sq_c)[:, None] + off
        cols = jnp.arange(sk_c)[None, :]
        ok = jnp.ones((sq_c, sk_c), bool)
        if causal:
            ok = rows >= cols
        if kv_len is not None:
            ok = ok & (cols < kv_len)
        return jnp.where(ok[None, None], s * scale, -1e30)

    if f32_operands:
        # baseline: f32 copies of K/V/P (simple, but 2x HBM bytes)
        def _attend(qc, kc, vc, off):
            s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32))
            s = _mask(s, qc.shape[1], kc.shape[1], off)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhqk,bkhd->bqhd", p, vc.astype(jnp.float32))
            return o.astype(q.dtype)
    else:
        # optimized: bf16 operands, f32 MXU accumulation; P downcast to the
        # operand dtype before P@V (flash-attention numerics)
        def _attend(qc, kc, vc, off):
            s = jax.lax.dot_general(
                qc.transpose(0, 2, 1, 3), kc.transpose(0, 2, 1, 3),
                (((3,), (3,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32)           # (B,H,q,k)
            s = _mask(s, qc.shape[1], kc.shape[1], off)
            p = jax.nn.softmax(s, axis=-1).astype(kc.dtype)
            o = jax.lax.dot_general(
                p, vc.transpose(0, 2, 1, 3),
                (((3,), (2,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32)           # (B,H,q,d)
            return o.transpose(0, 2, 1, 3).astype(q.dtype)

    if impl == "chunked" and sq > chunk and sq % chunk == 0:
        nc = sq // chunk
        if unroll:
            # python-unrolled (accounting / TPU-kernel stand-in): causal
            # chunks only attend to keys <= chunk end — the flash kernel's
            # block skipping (halves attention work for causal full-seq).
            # Valid with a progressively-written prefill cache too: causal
            # masking already excludes keys beyond the chunk end.
            skip = causal_skip and causal \
                and isinstance(q_offset, int) and q_offset == 0
            outs = []
            for i in range(nc):
                kend = (i + 1) * chunk if skip else k.shape[1]
                outs.append(_attend(q[:, i * chunk:(i + 1) * chunk],
                                    k[:, :kend], v[:, :kend],
                                    i * chunk + q_offset))
            out = jnp.concatenate(outs, axis=1)
        else:
            qs = q.reshape(b, nc, chunk, h, dh).transpose(1, 0, 2, 3, 4)
            offs = jnp.arange(nc) * chunk + q_offset

            def step(_, xs):
                qc, off = xs
                return None, _attend(qc, k, v, off)

            _, outs = jax.lax.scan(step, None, (qs, offs))
            out = outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)
    else:
        out = _attend(q, k, v, q_offset)
    return out


# ---------------------------------------------------------------------------
# GQA attention (starcoder2 / minitron / yi / deepseek-67b / mistral / whisper)
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, d_model=None, num_heads=None, num_kv_heads=None,
             head_dim=None, dtype=None):
    d = d_model or cfg.d_model
    h = num_heads or cfg.num_heads
    hkv = num_kv_heads or cfg.num_kv_heads
    dh = head_dim or cfg.resolved_head_dim
    dt = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * dh, dt),
        "wk": dense_init(ks[1], d, hkv * dh, dt),
        "wv": dense_init(ks[2], d, hkv * dh, dt),
        "wo": dense_init(ks[3], h * dh, d, dt),
    }


def gqa_attention(params, x: jax.Array, cfg, *, positions: jax.Array,
                  cache: KVCache | None = None, cache_index=None,
                  causal: bool = True, kv_x: jax.Array | None = None,
                  rope: bool = True, num_heads=None, num_kv_heads=None,
                  head_dim=None, impl=None, block_table=None, n_valid=None):
    """Returns (out (B,S,D), new_cache).

    ``kv_x``: cross-attention source (encoder output); disables cache rope.
    ``block_table``: (B, nblk) int32 — the cache leaves are then paged
    pools (num_blocks, block_size, ...) instead of dense (B, S, ...) slabs;
    decode writes at ``table[row, pos // bs]`` and attends over the gathered
    logical-order view.  With per-row ``cache_index`` the decode write may
    carry an S > 1 token window (speculative verify); ``n_valid``: optional
    (B,) count of real window tokens per row — the rest write nowhere and
    are masked out of attention via ``kv_len``.
    """
    b, s, d = x.shape
    h = num_heads or cfg.num_heads
    hkv = num_kv_heads or cfg.num_kv_heads
    dh = head_dim or cfg.resolved_head_dim
    q = quant_matmul(x, params["wq"], cfg.quant, "attn").reshape(b, s, h, dh)
    src = kv_x if kv_x is not None else x
    sk = src.shape[1]
    k = quant_matmul(src, params["wk"], cfg.quant, "attn").reshape(b, sk, hkv, dh)
    v = quant_matmul(src, params["wv"], cfg.quant, "attn").reshape(b, sk, hkv, dh)

    if rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    kv_len = None
    q_offset = 0
    if cache is not None and kv_x is None:
        if s == 1 and cfg.decode_attn == "sharded":
            from repro.parallel.act_sharding import current_mesh
            mesh = current_mesh()
            shard_axis = (cache.k.shape[0] if block_table is not None
                          else cache.k.shape[1])
            if mesh is not None and "model" in mesh.axis_names \
                    and shard_axis % mesh.shape["model"] == 0:
                from repro.serve.decode_attention import sharded_gqa_decode
                out, k_all, v_all = sharded_gqa_decode(
                    q, cache.k, cache.v, k, v, cache_index, mesh,
                    sm_scale=1.0 / float(dh) ** 0.5,
                    grouped_bf16=cfg.decode_attn_precision == "bf16_grouped",
                    block_table=block_table)
                out = out.reshape(b, s, h * dh)
                return (quant_matmul(out, params["wo"], cfg.quant, "attn"),
                        KVCache(k_all, v_all))
        if block_table is not None:
            # paged decode: write the new KV at the row's logical depth via
            # the block table, attend over the gathered logical-order view;
            # S > 1 is a speculative verify window (junk columns beyond
            # n_valid are OOB-dropped by the scatter and masked by kv_len)
            idx = jnp.asarray(cache_index, jnp.int32) \
                + jnp.zeros((b,), jnp.int32)
            if s == 1 and n_valid is None:
                k_pool = paged_write(cache.k, k, block_table, idx)
                v_pool = paged_write(cache.v, v, block_table, idx)
            else:
                k_pool = paged_write_window(cache.k, k, block_table, idx,
                                            n_valid)
                v_pool = paged_write_window(cache.v, v, block_table, idx,
                                            n_valid)
            valid = s if n_valid is None else n_valid
            new_cache = KVCache(k_pool, v_pool)
            k = paged_gather(k_pool, block_table)
            v = paged_gather(v_pool, block_table)
            out = sdpa(q, k, v, causal=causal, q_offset=idx,
                       kv_len=idx + valid,
                       impl=impl or cfg.attn_impl, chunk=cfg.attn_chunk,
                       unroll=not cfg.scan_layers, f32_operands=cfg.attn_f32,
                       fused_mask=cfg.attn_fused_mask,
                       causal_skip=cfg.attn_causal_skip)
            out = out.reshape(b, s, h * dh)
            return (quant_matmul(out, params["wo"], cfg.quant, "attn"),
                    new_cache)
        if getattr(cache_index, "ndim", 0) == 1:
            # per-row decode positions: every slab row writes its new KV at
            # its own depth (single batched scatter, static shapes); S > 1
            # is a speculative verify window
            if s == 1 and n_valid is None:
                rows = jnp.arange(b)
                k_all = cache.k.at[rows, cache_index].set(
                    k[:, 0].astype(cache.k.dtype))
                v_all = cache.v.at[rows, cache_index].set(
                    v[:, 0].astype(cache.v.dtype))
            else:
                k_all = dense_write_window(cache.k, k, cache_index, n_valid)
                v_all = dense_write_window(cache.v, v, cache_index, n_valid)
        else:
            k_all = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), (0, cache_index, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), (0, cache_index, 0, 0))
        new_cache = KVCache(k_all, v_all)
        k, v = k_all, v_all
        kv_len = cache_index + (s if n_valid is None else n_valid)
        q_offset = cache_index

    out = sdpa(q, k, v, causal=causal and kv_x is None, q_offset=q_offset,
               kv_len=kv_len, impl=impl or cfg.attn_impl, chunk=cfg.attn_chunk,
               unroll=not cfg.scan_layers, f32_operands=cfg.attn_f32,
               fused_mask=cfg.attn_fused_mask,
               causal_skip=cfg.attn_causal_skip)
    out = out.reshape(b, s, h * dh)
    return quant_matmul(out, params["wo"], cfg.quant, "attn"), new_cache


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v2): compressed KV cache (c_kv + shared k_rope)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dt = jnp.dtype(cfg.dtype)
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 7)
    p = {
        "w_dkv": dense_init(ks[0], d, m.kv_lora_rank + m.qk_rope_dim, dt),
        "w_uk": dense_init(ks[1], m.kv_lora_rank, h * m.qk_nope_dim, dt),
        "w_uv": dense_init(ks[2], m.kv_lora_rank, h * m.v_dim, dt),
        "wo": dense_init(ks[3], h * m.v_dim, d, dt),
    }
    if m.q_lora_rank:
        p["w_dq"] = dense_init(ks[4], d, m.q_lora_rank, dt)
        p["w_uq"] = dense_init(ks[5], m.q_lora_rank, h * qd, dt)
    else:
        p["wq"] = dense_init(ks[6], d, h * qd, dt)
    return p


def mla_attention(params, x: jax.Array, cfg, *, positions: jax.Array,
                  cache: KVCache | None = None, cache_index=None,
                  block_table=None, n_valid=None):
    """MLA with the compressed-cache decode path.

    Cache stores (c_kv (B,S,R), k_rope (B,S,dr)) — the 'absorbed' form keeps
    decode FLOPs at O(R + dr) per head instead of materializing per-head K/V.
    ``block_table``: (B, nblk) — cache leaves are paged pools
    (num_blocks, block_size, R) / (num_blocks, block_size, dr); ``n_valid``:
    (B,) real-token counts for an S > 1 speculative verify window; see
    :func:`gqa_attention`.
    """
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.num_heads
    qd = m.qk_nope_dim + m.qk_rope_dim

    if m.q_lora_rank:
        q = quant_matmul(quant_matmul(x, params["w_dq"], cfg.quant, "attn"),
                         params["w_uq"], cfg.quant, "attn")
    else:
        q = quant_matmul(x, params["wq"], cfg.quant, "attn")
    q = q.reshape(b, s, h, qd)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = quant_matmul(x, params["w_dkv"], cfg.quant, "attn")
    c_kv, k_rope = dkv[..., :m.kv_lora_rank], dkv[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)

    kv_len = None
    q_offset = 0
    new_cache = None
    if cache is not None and s == 1 and cfg.decode_attn == "sharded":
        from repro.parallel.act_sharding import current_mesh
        mesh = current_mesh()
        shard_axis = (cache.k.shape[0] if block_table is not None
                      else cache.k.shape[1])
        if mesh is not None and "model" in mesh.axis_names \
                and shard_axis % mesh.shape["model"] == 0:
            from repro.serve.decode_attention import sharded_mla_decode
            w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
            q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                               w_uk.astype(jnp.float32))
            ctx_c, c_all, r_all = sharded_mla_decode(
                q_abs, q_rope.astype(jnp.float32), cache.k, cache.v,
                c_kv, k_rope, cache_index, mesh,
                sm_scale=1.0 / float(qd) ** 0.5, block_table=block_table)
            w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_dim)
            ctx = jnp.einsum("bqhr,rhd->bqhd", ctx_c.astype(jnp.float32),
                             w_uv.astype(jnp.float32))
            ctx = ctx.reshape(b, s, h * m.v_dim).astype(x.dtype)
            return (quant_matmul(ctx, params["wo"], cfg.quant, "attn"),
                    KVCache(c_all, r_all))
    if cache is not None:
        if block_table is not None:
            idx = jnp.asarray(cache_index, jnp.int32) \
                + jnp.zeros((b,), jnp.int32)
            if s == 1 and n_valid is None:
                c_all = paged_write(cache.k, c_kv, block_table, idx)
                r_all = paged_write(cache.v, k_rope, block_table, idx)
            else:
                c_all = paged_write_window(cache.k, c_kv, block_table, idx,
                                           n_valid)
                r_all = paged_write_window(cache.v, k_rope, block_table, idx,
                                           n_valid)
            new_cache = KVCache(c_all, r_all)
            c_kv = paged_gather(c_all, block_table)
            k_rope = paged_gather(r_all, block_table)
            kv_len = idx + (s if n_valid is None else n_valid)
            q_offset = idx
        elif getattr(cache_index, "ndim", 0) == 1:
            if s == 1 and n_valid is None:
                rows = jnp.arange(b)
                c_all = cache.k.at[rows, cache_index].set(
                    c_kv[:, 0].astype(cache.k.dtype))
                r_all = cache.v.at[rows, cache_index].set(
                    k_rope[:, 0].astype(cache.v.dtype))
            else:
                c_all = dense_write_window(cache.k, c_kv, cache_index,
                                           n_valid)
                r_all = dense_write_window(cache.v, k_rope, cache_index,
                                           n_valid)
        else:
            c_all = jax.lax.dynamic_update_slice(
                cache.k, c_kv.astype(cache.k.dtype), (0, cache_index, 0))
            r_all = jax.lax.dynamic_update_slice(
                cache.v, k_rope.astype(cache.v.dtype), (0, cache_index, 0))
        if block_table is None:
            new_cache = KVCache(c_all, r_all)
            c_kv, k_rope = c_all, r_all
            kv_len = cache_index + (s if n_valid is None else n_valid)
            q_offset = cache_index

    sk = c_kv.shape[1]
    # Absorbed scores: q_nope^T (W_uk c) == (q_nope W_uk^T)^T c
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_dim)
    q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))          # (B,Sq,H,R)
    c_f = c_kv.astype(jnp.float32)
    r_f = k_rope.astype(jnp.float32)
    inv_sqrt = 1.0 / jnp.sqrt(qd).astype(jnp.float32)

    def _chunk(qa, qr, off):
        s_c = jnp.einsum("bqhr,bkr->bhqk", qa, c_f)
        s_r = jnp.einsum("bqhd,bkd->bhqk", qr, r_f)
        scores = (s_c + s_r) * inv_sqrt
        bias = _bias(qa.shape[1], sk, off, True, kv_len)
        if bias.ndim == 2:            # scalar offsets: broadcast over (B, H)
            bias = bias[None, None]
        scores = scores + bias
        p = jax.nn.softmax(scores, axis=-1)
        return jnp.einsum("bhqk,bkr->bqhr", p, c_f)       # (B,cq,H,R)

    cq = cfg.attn_chunk
    if s > cq and s % cq == 0:
        nc = s // cq
        if not cfg.scan_layers:   # accounting mode: unrolled python loop
            outs = [_chunk(q_abs[:, i * cq:(i + 1) * cq],
                           q_rope.astype(jnp.float32)[:, i * cq:(i + 1) * cq],
                           i * cq + q_offset) for i in range(nc)]
            ctx_c = jnp.concatenate(outs, axis=1)
        else:
            qa_s = q_abs.reshape(b, nc, cq, h, -1).transpose(1, 0, 2, 3, 4)
            qr_s = (q_rope.astype(jnp.float32)
                    .reshape(b, nc, cq, h, -1).transpose(1, 0, 2, 3, 4))
            offs = jnp.arange(nc) * cq + q_offset

            def step(_, xs):
                qa, qr, off = xs
                return None, _chunk(qa, qr, off)

            _, outs = jax.lax.scan(step, None, (qa_s, qr_s, offs))
            ctx_c = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h,
                                                          m.kv_lora_rank)
    else:
        ctx_c = _chunk(q_abs, q_rope.astype(jnp.float32), q_offset)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, m.v_dim)
    ctx = jnp.einsum("bqhr,rhd->bqhd", ctx_c, w_uv.astype(jnp.float32))
    ctx = ctx.reshape(b, s, h * m.v_dim).astype(x.dtype)
    return quant_matmul(ctx, params["wo"], cfg.quant, "attn"), new_cache
