"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Per the assignment, the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, enc_seq, d_model).  The transformer backbone
(bidirectional encoder; causal decoder with cross-attention) is real.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models import attention as attn_mod
from repro.models.attention import KVCache, init_gqa
from repro.models.common import (dense_init, embed_init, gather_last,
                                 reject_paged_spec, remat_policy_of,
                                 rms_norm, token_positions)
from repro.models.mlp import init_mlp, mlp
from repro.models.transformer import chunked_xent


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": init_gqa(ks[0], cfg),
        "mlp": init_mlp(ks[1], cfg),
    }


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "ln3": jnp.ones((cfg.d_model,), jnp.float32),
        "self_attn": init_gqa(ks[0], cfg),
        "cross_attn": init_gqa(ks[1], cfg),
        "mlp": init_mlp(ks[2], cfg),
    }


class EncDecLM:
    def __init__(self, cfg):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        ed = cfg.encdec
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 5)
        return {
            "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
            "lm_head": dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt),
            "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(
                jax.random.split(ks[2], ed.enc_layers)),
            "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(
                jax.random.split(ks[3], cfg.num_layers)),
            "ln_enc": jnp.ones((cfg.d_model,), jnp.float32),
            "ln_dec": jnp.ones((cfg.d_model,), jnp.float32),
        }

    def encode(self, params, frames):
        """frames: (B, T_enc, D) stubbed frontend output."""
        cfg = self.cfg
        b, t, _ = frames.shape
        positions = jnp.arange(t)[None, :]
        x = frames

        def body(h, p_i):
            a, _ = attn_mod.gqa_attention(
                p_i["attn"], rms_norm(h, p_i["ln1"], cfg.norm_eps), cfg,
                positions=positions, causal=False)
            h = h + a
            f = mlp(p_i["mlp"], rms_norm(h, p_i["ln2"], cfg.norm_eps), cfg,
                    mlp_type="gelu")
            return h + f, None

        if not cfg.scan_layers:
            n = jax.tree.leaves(params["enc_blocks"])[0].shape[0]
            for i in range(n):
                x, _ = body(x, jax.tree.map(lambda a: a[i],
                                            params["enc_blocks"]))
        else:
            x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    def decode(self, params, tokens, enc_out, *, caches=None, cache_index=0,
               training=False):
        cfg = self.cfg
        x = params["embed"][tokens]
        b, s, _ = x.shape
        positions = token_positions(s, cache_index)

        def body(carry, xs):
            h = carry
            p_i, cache_i = xs
            a, new_cache = attn_mod.gqa_attention(
                p_i["self_attn"], rms_norm(h, p_i["ln1"], cfg.norm_eps), cfg,
                positions=positions, cache=cache_i, cache_index=cache_index)
            h = h + a
            c, _ = attn_mod.gqa_attention(
                p_i["cross_attn"], rms_norm(h, p_i["ln2"], cfg.norm_eps), cfg,
                positions=positions, kv_x=enc_out, causal=False)
            h = h + c
            f = mlp(p_i["mlp"], rms_norm(h, p_i["ln3"], cfg.norm_eps), cfg,
                    mlp_type="gelu")
            return h + f, new_cache

        if training and cfg.remat:
            body = jax.checkpoint(
                body, policy=remat_policy_of(cfg))
        if not cfg.scan_layers:
            n = jax.tree.leaves(params["dec_blocks"])[0].shape[0]
            ncs = []
            for i in range(n):
                p_i = jax.tree.map(lambda a: a[i], params["dec_blocks"])
                c_i = (jax.tree.map(lambda a: a[i], caches)
                       if caches is not None else None)
                x, nc = body(x, (p_i, c_i))
                ncs.append(nc)
            new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ncs)
                          if caches is not None else None)
        else:
            x, new_caches = jax.lax.scan(body, x,
                                         (params["dec_blocks"], caches))
        x = rms_norm(x, params["ln_dec"], cfg.norm_eps)
        return x, new_caches

    def loss(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        hidden, _ = self.decode(params, batch["tokens"], enc_out,
                                training=True)
        xent = chunked_xent(hidden, params["lm_head"], batch["labels"],
                            batch.get("loss_mask"),
                            unroll=not self.cfg.scan_layers)
        return xent, {"xent": xent}

    def init_cache(self, batch: int, s_max: int, *, spec=None):
        """Uniform contract: decoder self-attention KV only; a paged spec
        is rejected (the engine does not page modality backbones yet)."""
        reject_paged_spec(spec, "encdec", "the decoder KV slab is served "
                          "dense (no engine-managed block tables)")
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        shape = (cfg.num_layers, batch, s_max, hkv, dh)
        return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt))

    def prefill(self, params, tokens, caches, *, frames, last_pos=None):
        enc_out = self.encode(params, frames)
        hidden, new_caches = self.decode(params, tokens, enc_out,
                                         caches=caches, cache_index=0)
        last = (hidden[:, -1:] if last_pos is None
                else gather_last(hidden, last_pos))
        logits = quant_matmul(last, params["lm_head"], None)
        return logits, (new_caches, enc_out)

    def decode_step(self, params, token, state, index, *, tables=None):
        """``index``: scalar or (B,) per-row decoder positions.  ``tables``
        must be None (dense decoder KV) — accepted for the uniform engine
        contract."""
        assert tables is None, "encdec caches are dense (no block table)"
        caches, enc_out = state
        hidden, new_caches = self.decode(params, token, enc_out,
                                         caches=caches, cache_index=index)
        logits = quant_matmul(hidden, params["lm_head"], None)
        return logits, (new_caches, enc_out)
