"""LLaVA-NeXT-style VLM: stub vision frontend + Mistral-7B text backbone.

Per the assignment the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, num_patches, d_model) — the anyres
tiling/CLIP tower are out of scope.  The multimodal sequence is
[patches; text] and the backbone is the standard decoder-only transformer.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import reject_paged_spec
from repro.models.transformer import TransformerLM


class VLM:
    def __init__(self, cfg):
        self.cfg = cfg
        self.backbone = TransformerLM(cfg)

    def init(self, key):
        return self.backbone.init(key)

    def _merge(self, params, patches, tokens):
        tok_embeds = params["embed"][tokens]
        return jnp.concatenate([patches.astype(tok_embeds.dtype), tok_embeds],
                               axis=1)

    def loss(self, params, batch):
        """batch: patches (B,P,D), tokens (B,S_text), labels (B,P+S_text),
        loss_mask zeroing the patch positions."""
        embeds = self._merge(params, batch["patches"], batch["tokens"])
        b, s, _ = embeds.shape
        p = batch["patches"].shape[1]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.concatenate(
                [jnp.zeros((b, p), jnp.float32),
                 jnp.ones((b, s - p), jnp.float32)], axis=1)
        hidden, aux, _ = self.backbone.forward(params, embeds=embeds,
                                               training=True)
        from repro.models.transformer import chunked_xent
        head = params["lm_head"]
        xent = chunked_xent(hidden, head, batch["labels"], mask)
        return xent + aux, {"xent": xent}

    def init_cache(self, batch: int, s_max: int, *, spec=None):
        """Uniform contract: the text-only engine does not page modality
        backbones yet, so a paged spec is rejected explicitly."""
        reject_paged_spec(spec, "vlm", "the multimodal backbone is served "
                          "dense (no engine-managed block tables)")
        return self.backbone.init_cache(batch, s_max)

    def prefill(self, params, tokens, caches, *, patches, last_pos=None):
        from repro.models.common import gather_last
        embeds = self._merge(params, patches, tokens)
        hidden, _, new_caches = self.backbone.forward(
            params, embeds=embeds, caches=caches, cache_index=0)
        last = (hidden[:, -1:] if last_pos is None
                else gather_last(hidden, last_pos))
        logits = self.backbone.logits(params, last)
        return logits, new_caches

    def decode_step(self, params, token, state, index, *, tables=None):
        """``index``: scalar or (B,) per-row positions.  ``tables`` must be
        None (dense backbone cache) — accepted for the uniform engine
        contract."""
        return self.backbone.decode_step(params, token, state, index,
                                         tables=tables)
