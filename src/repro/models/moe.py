"""Token-choice top-k MoE with shared experts (DeepSeek-V2 style).

Dispatch is *group-local* expert-choice over routed tokens: tokens are
grouped by batch row (training/prefill) or into one group (decode), each
expert picks its top-``capacity`` tokens per group by router probability,
the picks are gathered into a (G, E, C, D) buffer, processed by batched
expert matmuls (EP: experts sharded over ``model``), and scattered back
weighted by router probs.  All shapes are static (dry-run/SPMD friendly);
group-locality keeps the top-k off the sharded token axis so no global
gather materializes.  Capacity overflow drops tokens (standard semantics);
the shared experts provide the residual path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models.common import dense_init


def init_moe(key, cfg):
    mc = cfg.moe
    d, ff = cfg.d_model, mc.d_expert
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 7)
    e = mc.num_experts
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        # stacked expert weights: (E, d, ff) / (E, ff, d)
        "w_gate": (jax.random.normal(ks[1], (e, d, ff)) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, ff)) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, ff, d)) *
                   (1.0 / jnp.sqrt(ff))).astype(dt),
    }
    if mc.num_shared:
        sff = ff * mc.num_shared
        p["shared"] = {
            "w_gate": dense_init(ks[4], d, sff, dt),
            "w_up": dense_init(ks[5], d, sff, dt),
            "w_down": dense_init(ks[6], sff, d, dt),
        }
    return p


def _capacity(group_tokens: int, cfg) -> int:
    mc = cfg.moe
    cap = int(group_tokens * mc.top_k * mc.capacity_factor / mc.num_experts)
    return min(group_tokens, max(4, (cap + 3) // 4 * 4))


def moe_ffn(params, x: jax.Array, cfg, *, window: bool = False):
    """x: (B, S, D) -> (out, aux_loss).

    ``window=True``: x is a speculative verify/commit window, not a
    prefill — group by COLUMN (S groups of B tokens) so the tokens at
    window offset j compete for expert capacity exactly like the plain
    decode tick that would have processed them (same group size, same
    capacity, so the no-drop regime is identical).  Row-grouping would
    make a token's routing depend on its own row's draft width.
    """
    mc = cfg.moe
    b, s, d = x.shape
    e = mc.num_experts
    # group by batch row; decode (s==1) folds the batch into one group so
    # capacity stays ~top_k/E per token instead of all-experts-per-token
    if s == 1:
        xg_in = x.reshape(1, b, d)
    elif window:
        xg_in = x.transpose(1, 0, 2)
    else:
        xg_in = x
    g, n, _ = xg_in.shape
    cap = _capacity(n, cfg)

    logits = (xg_in.astype(jnp.float32) @ params["router"])     # (G, N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, mc.top_k)               # (G, N, K)

    # Switch-style load-balance aux loss
    importance = probs.mean((0, 1))                             # (E,)
    load = jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32).mean((0, 1))
    aux = e * jnp.sum(importance * load) * mc.aux_loss_coef

    # gate[g, n, e] = prob if e in top-k else 0
    gates = jnp.zeros((g, n, e), jnp.float32).at[
        jnp.arange(g)[:, None, None], jnp.arange(n)[None, :, None],
        top_e].set(top_p)
    # expert-choice among routed tokens: (G, E, C)
    sel_gate, sel_idx = jax.lax.top_k(gates.transpose(0, 2, 1), cap)
    valid = (sel_gate > 0.0).astype(jnp.float32)

    def gather_g(xs, idx):                                      # (N,D),(E,C)
        return xs[idx.reshape(-1)].reshape(e, cap, d)

    xg = jax.vmap(gather_g)(xg_in, sel_idx)                     # (G, E, C, D)
    xg = xg * valid[..., None].astype(xg.dtype)

    gate_h = jnp.einsum("gecd,edf->gecf", xg, params["w_gate"])
    up_h = jnp.einsum("gecd,edf->gecf", xg, params["w_up"])
    h = jax.nn.silu(gate_h) * up_h
    yg = jnp.einsum("gecf,efd->gecd", h, params["w_down"])      # (G, E, C, D)
    yg = yg * (sel_gate * valid)[..., None].astype(yg.dtype)

    def scatter_g(ys, idx):                                     # (E,C,D),(E,C)
        return jnp.zeros((n, d), ys.dtype).at[idx.reshape(-1)].add(
            ys.reshape(-1, d))

    out = jax.vmap(scatter_g)(yg, sel_idx)                      # (G, N, D)
    if s > 1 and window:
        out = out.transpose(1, 0, 2)
    out = out.reshape(b, s, d)

    if mc.num_shared:
        sp = params["shared"]
        gate = quant_matmul(x, sp["w_gate"], cfg.quant, "moe")
        up = quant_matmul(x, sp["w_up"], cfg.quant, "moe")
        out = out + quant_matmul(jax.nn.silu(gate) * up, sp["w_down"],
                                 cfg.quant, "moe")
    return out.astype(x.dtype), aux
