"""Zamba2-style hybrid: Mamba2 backbone + one weight-SHARED attention block
applied every ``period`` layers.

Layers are processed in groups: [shared attn+MLP block] -> scan over
``period`` mamba2 layers.  The shared block's *weights* are reused at every
application point, but each application keeps its own KV cache (stacked over
groups) — the hybrid runs the ``long_500k`` cell with the attention caches
sharded over the model axis.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models import attention as attn_mod
from repro.models.attention import KVCache, init_gqa
from repro.models.common import (dense_init, embed_init, gather_last,
                                 remat_policy_of, rms_norm, token_positions)
from repro.models.mlp import init_mlp, mlp
from repro.models.ssm import (SSMCache, init_mamba2, mamba2_block,
                              snapshot_row, ssm_cache_shape)
from repro.models.transformer import chunked_xent


class HybridLM:
    def __init__(self, cfg):
        self.cfg = cfg
        hc = cfg.hybrid
        self.num_groups = (cfg.num_layers + hc.period - 1) // hc.period

    def init(self, key):
        cfg = self.cfg
        hc = cfg.hybrid
        dt = jnp.dtype(cfg.dtype)
        ks = jax.random.split(key, 6)
        shared = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_gqa(ks[0], cfg, num_heads=hc.shared_num_heads,
                             num_kv_heads=hc.shared_num_kv_heads,
                             head_dim=cfg.d_model // hc.shared_num_heads),
            "mlp": init_mlp(ks[1], cfg, d_ff=hc.shared_d_ff),
        }
        mamba = jax.vmap(lambda k: {
            "ln": jnp.ones((cfg.d_model,), jnp.float32),
            "m": init_mamba2(k, cfg)})(
                jax.random.split(ks[2], cfg.num_layers))
        return {
            "embed": embed_init(ks[3], cfg.vocab_size, cfg.d_model, dt),
            "lm_head": dense_init(ks[4], cfg.d_model, cfg.vocab_size, dt),
            "shared": shared,
            "mamba": mamba,
            "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        }

    def _shared_attn(self, params, x, positions, cache, cache_index,
                     block_table=None, n_valid=None):
        cfg = self.cfg
        hc = cfg.hybrid
        p = params["shared"]
        a, new_cache = attn_mod.gqa_attention(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg,
            positions=positions, cache=cache, cache_index=cache_index,
            num_heads=hc.shared_num_heads,
            num_kv_heads=hc.shared_num_kv_heads,
            head_dim=cfg.d_model // hc.shared_num_heads,
            block_table=block_table, n_valid=n_valid)
        x = x + a
        f = mlp(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg,
                mlp_type="swiglu")
        return x + f, new_cache

    def forward(self, params, tokens, *, caches=None, cache_index=0,
                training=False, last_pos=None, block_tables=None,
                n_valid=None):
        cfg = self.cfg
        hc = cfg.hybrid
        x = params["embed"][tokens]
        b, s, _ = x.shape
        positions = token_positions(s, cache_index)
        attn_caches, ssm_caches = (caches if caches is not None
                                   else (None, None))

        from repro.parallel.act_sharding import shard_hidden

        def mamba_body(carry, xs):
            h = carry
            p_i, cache_i = xs
            h = shard_hidden(h)
            y, new_cache = mamba2_block(
                p_i["m"], rms_norm(h, p_i["ln"], cfg.norm_eps), cfg,
                cache=cache_i, last_pos=last_pos)
            return shard_hidden(h + y), new_cache

        if training and cfg.remat:
            mamba_body = jax.checkpoint(
                mamba_body, policy=remat_policy_of(cfg))

        new_attn_caches, new_ssm_caches = [], []
        layer0 = 0
        for g in range(self.num_groups):
            ac = attn_caches[g] if attn_caches is not None else None
            x, nac = self._shared_attn(params, x, positions, ac, cache_index,
                                       block_table=block_tables,
                                       n_valid=n_valid)
            new_attn_caches.append(nac)
            n_in_group = min(hc.period, cfg.num_layers - layer0)
            p_g = jax.tree.map(lambda a: a[layer0:layer0 + n_in_group],
                               params["mamba"])
            sc = (jax.tree.map(lambda a: a[layer0:layer0 + n_in_group],
                               ssm_caches)
                  if ssm_caches is not None else None)
            if not cfg.scan_layers:
                ncs = []
                for i in range(n_in_group):
                    p_i = jax.tree.map(lambda a: a[i], p_g)
                    c_i = (jax.tree.map(lambda a: a[i], sc)
                           if sc is not None else None)
                    x, nc = mamba_body(x, (p_i, c_i))
                    ncs.append(nc)
                nsc = (jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ncs)
                       if sc is not None else None)
            else:
                x, nsc = jax.lax.scan(mamba_body, x, (p_g, sc))
            new_ssm_caches.append(nsc)
            layer0 += n_in_group
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        if caches is not None:
            new_caches = (new_attn_caches,
                          jax.tree.map(lambda *xs: jnp.concatenate(xs, 0),
                                       *new_ssm_caches))
        else:
            new_caches = None
        return x, new_caches

    def loss(self, params, batch):
        hidden, _ = self.forward(params, batch["tokens"], training=True)
        xent = chunked_xent(hidden, params["lm_head"], batch["labels"],
                            batch.get("loss_mask"),
                            unroll=not self.cfg.scan_layers)
        return xent, {"xent": xent}

    def init_cache(self, batch: int, s_max: int, *, spec=None):
        """SPLIT SUBSTRATE: with a paged ``spec`` the shared attention
        block's KV leaves become paged pools
        (num_blocks, block_size, Hkv, Dh) shared by all slots (one block
        table per slot, reused by every group), while the recurrent SSM
        state — O(1) per slot, nothing to page — stays dense (L, B, ...)."""
        cfg = self.cfg
        hc = cfg.hybrid
        dt = jnp.dtype(cfg.dtype)
        hd = cfg.d_model // hc.shared_num_heads
        if spec is not None and spec.paged:
            kv_shape = (spec.num_blocks, spec.block_size,
                        hc.shared_num_kv_heads, hd)
        else:
            kv_shape = (batch, s_max, hc.shared_num_kv_heads, hd)
        attn_caches = [KVCache(jnp.zeros(kv_shape, dt),
                               jnp.zeros(kv_shape, dt))
                       for _ in range(self.num_groups)]
        conv_s, state_s = ssm_cache_shape(cfg, batch)
        ssm_caches = SSMCache(
            jnp.zeros((cfg.num_layers,) + conv_s, dt),
            jnp.zeros((cfg.num_layers,) + state_s, jnp.float32))
        return (attn_caches, ssm_caches)

    def state_snapshot(self, caches, row: int = 0):
        """Prefix-cache export: only the SSM half of the split substrate —
        the attention KV for the same boundary lives in (refcount-shared)
        paged-pool blocks, not in the snapshot."""
        _, ssm_caches = caches
        return snapshot_row(ssm_caches, row)

    def seed_from_snapshot(self, staging, snap):
        """Warm admission: keep the staging attention leaves (the engine
        has already gathered the cached prefix KV into them) and swap in
        the snapshot's recurrent state."""
        attn_staging, _ = staging
        return (attn_staging, snap)

    def prefill(self, params, tokens, caches, *, last_pos=None,
                cache_index=0):
        """``last_pos``: (B,) per-row last REAL token of a right-padded
        bucket — attention masks pad keys causally; the SSM layers mask
        them out of the recurrent state (masked SSD scan).  ``cache_index``
        > 0 continues a chunked prefill: attention writes the chunk at the
        offset, the SSM scan resumes from the carried (conv, state)."""
        hidden, new_caches = self.forward(params, tokens, caches=caches,
                                          cache_index=cache_index,
                                          last_pos=last_pos)
        last = (hidden[:, -1:] if last_pos is None
                else gather_last(hidden, last_pos))
        logits = quant_matmul(last, params["lm_head"], None)
        return logits, new_caches

    def decode_step(self, params, token, state, index, *, tables=None):
        """``index``: scalar or (B,) per-row positions (attention caches
        honor per-row depths; the SSM state recurrence is position-free).
        ``tables``: (B, nblk) int32 when the ATTENTION leaves are paged
        pools (split substrate) — the SSM state is always dense."""
        hidden, new_caches = self.forward(params, token, caches=state,
                                          cache_index=index,
                                          block_tables=tables)
        logits = quant_matmul(hidden, params["lm_head"], None)
        return logits, new_caches

    def decode_window(self, params, tokens, state, index, *, tables=None,
                      n_valid=None, last_pos=None):
        """Speculative verify/commit over a (B, W) window on the SPLIT
        substrate: the shared attention block writes the window at per-row
        depths (``n_valid`` columns real, the rest dropped + masked), the
        mamba layers run the masked SSD scan bounded by ``last_pos`` —
        verify uses ``last_pos = n_valid - 1``, a partial-accept commit
        re-runs from the pre-verify tree with ``last_pos = accepts`` (the
        attention half then rewrites identical values at positions <= the
        accept point; its rejected KV beyond is dead weight)."""
        if last_pos is None and n_valid is not None:
            last_pos = jnp.asarray(n_valid, jnp.int32) - 1
        hidden, new_caches = self.forward(params, tokens, caches=state,
                                          cache_index=index,
                                          last_pos=last_pos,
                                          block_tables=tables,
                                          n_valid=n_valid)
        logits = quant_matmul(hidden, params["lm_head"], None)
        return logits, new_caches
