"""Feed-forward blocks: SwiGLU (llama family) and GELU (starcoder2/whisper).

Every projection (``w_up``/``w_gate``/``w_down``) routes through
``core.layers.quant_matmul``, so these leaves participate in BOTH
quantization surfaces: model-level ``QuantConfig`` (dynamic, every call)
and engine-level ``EngineConfig(quant="lut4"|"int4")``, where the serving
backend freezes them to 4-bit ``QuantizedWeight`` containers for the
decode hot path (prefill keeps the float tree).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import quant_matmul
from repro.models.common import dense_init


def init_mlp(key, cfg, d_model=None, d_ff=None, mlp_type=None, dtype=None):
    d = d_model or cfg.d_model
    ff = d_ff or cfg.d_ff
    mt = mlp_type or cfg.mlp_type
    dt = dtype or jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, ff, dt),
         "w_down": dense_init(ks[1], ff, d, dt)}
    if mt == "swiglu":
        p["w_gate"] = dense_init(ks[2], d, ff, dt)
    return p


def mlp(params, x: jax.Array, cfg, mlp_type=None) -> jax.Array:
    mt = mlp_type or cfg.mlp_type
    up = quant_matmul(x, params["w_up"], cfg.quant, "mlp")
    if mt == "swiglu":
        gate = quant_matmul(x, params["w_gate"], cfg.quant, "mlp")
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return quant_matmul(h, params["w_down"], cfg.quant, "mlp")
