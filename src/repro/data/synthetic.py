"""Deterministic synthetic LM data pipeline, sharded across hosts.

Produces a learnable (not pure-noise) stream so examples/e2e training shows a
real loss curve: tokens follow a fixed random bigram chain plus noise, so a
model can reduce loss well below uniform entropy.  Every batch is a pure
function of (seed, step) — restarts and elastic resharding reproduce the
exact stream with no data-state checkpointing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, noise: float = 0.3):
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.chain = rng.integers(0, vocab_size, vocab_size)  # bigram map

    def batch_np(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.global_batch, self.seq + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.global_batch)
        noise_mask = rng.random((self.global_batch, self.seq)) < self.noise
        noise_tok = rng.integers(0, self.vocab, (self.global_batch, self.seq))
        for t in range(self.seq):
            nxt = self.chain[toks[:, t]]
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def batch(self, step: int, shardings: dict | None = None) -> dict:
        arrs = self.batch_np(step)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in arrs.items()}
        return {k: jax.device_put(v, shardings[k]) for k, v in arrs.items()}
