"""Token sampling for the serving engine: greedy / temperature / top-k.

Pure-functional and jit-friendly: ``sample`` maps (logits, key) -> token ids
with static shapes, so the engine threads one PRNG key through the whole
serve loop and every run with the same seed is bit-reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

MODES = ("greedy", "temperature", "top_k")


@dataclass(frozen=True)
class SamplingConfig:
    """``mode``: one of :data:`MODES`.

    * ``greedy`` — argmax (temperature/top_k ignored).
    * ``temperature`` — softmax sampling of logits / temperature.
    * ``top_k`` — restrict to the k highest logits, then temperature-sample.
    """
    mode: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.mode in ("temperature", "top_k") and self.temperature <= 0:
            raise ValueError("temperature must be > 0")
        if self.mode == "top_k" and self.top_k <= 0:
            raise ValueError("top_k mode needs top_k >= 1")


def sample(logits: jax.Array, key: jax.Array, cfg: SamplingConfig
           ) -> jax.Array:
    """logits: (B, V) -> (B,) int32 next-token ids.

    One key samples the whole batch (``jax.random.categorical`` is
    vectorized over leading axes).  Determinism is per serve run: a fixed
    engine seed replays the identical schedule bit-for-bit, but a request's
    stream DOES depend on its slot index and co-tenants (the per-row noise
    is a function of row position in the batch).
    """
    if cfg.mode == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.mode == "top_k":
        k = min(cfg.top_k, logits.shape[-1])
        kth = jax.lax.top_k(logits, k)[0][..., -1:]       # (B, 1)
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
