"""Token sampling for the serving engine: greedy / temperature / top-k.

Pure-functional and jit-friendly: ``sample`` maps (logits, key) -> token ids
with static shapes.  When the caller passes per-row ``rids``/``steps``, each
row draws from its own PRNG stream ``fold_in(fold_in(key, rid), step)`` —
a request's sampled tokens are then a function of (engine seed, rid, step)
only, independent of its slot index, its co-tenants, and the scheduling
order (mixed-batch == sequential for every sampling mode).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

MODES = ("greedy", "temperature", "top_k")


@dataclass(frozen=True)
class SamplingConfig:
    """``mode``: one of :data:`MODES`.

    * ``greedy`` — argmax (temperature/top_k ignored).
    * ``temperature`` — softmax sampling of logits / temperature.
    * ``top_k`` — restrict to the k highest logits, then temperature-sample.
    """
    mode: str = "greedy"
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.mode in ("temperature", "top_k") and self.temperature <= 0:
            raise ValueError("temperature must be > 0")
        if self.mode == "top_k" and self.top_k <= 0:
            raise ValueError("top_k mode needs top_k >= 1")


def per_request_keys(key: jax.Array, rids: jax.Array, steps: jax.Array
                     ) -> jax.Array:
    """One PRNG key per row: ``fold_in(fold_in(key, rid), step)``."""
    def one(rid, step):
        return jax.random.fold_in(jax.random.fold_in(key, rid), step)
    return jax.vmap(one)(rids, steps)


def sample(logits: jax.Array, key: jax.Array, cfg: SamplingConfig,
           *, rids: jax.Array | None = None,
           steps: jax.Array | None = None) -> jax.Array:
    """logits: (B, V) -> (B,) int32 next-token ids.

    Without ``rids``, one key samples the whole batch row-wise (legacy: a
    request's stream then depends on its slot and co-tenants).  With
    ``rids``/``steps`` (both (B,) int32), every row samples from its own
    per-request stream, reproducible regardless of scheduling.
    """
    if cfg.mode == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / cfg.temperature
    if cfg.mode == "top_k":
        k = min(cfg.top_k, logits.shape[-1])
        kth = jax.lax.top_k(logits, k)[0][..., -1:]       # (B, 1)
        logits = jnp.where(logits >= kth, logits, -jnp.inf)
    if rids is None:
        return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    assert steps is not None, "per-request sampling needs rids AND steps"
    keys = per_request_keys(key, jnp.asarray(rids, jnp.int32),
                            jnp.asarray(steps, jnp.int32))
    toks = jax.vmap(lambda k_, l: jax.random.categorical(k_, l))(keys, logits)
    return toks.astype(jnp.int32)
