"""Continuous-batching serving engine: batched prefill + mixed-depth decode.

A fixed set of ``max_batch`` sequence slots.  New requests are bucketed by
padded prompt length and prefilled in ONE jit call per bucket (rows are
written into the slab caches with a single batched scatter); every decode
tick advances all active slots one token **at their own position** — a
``(max_batch,)`` int32 position array is threaded through
``model.decode_step`` so rows of different depths attend over exactly their
own prefix (static shapes: jit caches one decode program plus one prefill
program per bucket shape).

**Serving API v2.**  All knobs live in one validated
:class:`repro.serve.config.EngineConfig` (``Engine(cfg, params,
EngineConfig(...))``; the legacy ``Engine(cfg, params, **knobs)`` shim was
removed after its one-release deprecation window).  ``submit()`` returns a
:class:`RequestHandle` — incremental token streaming (generator and
on-token callback), ``cancel()`` that releases blocks and staged state
mid-admission, and truthiness preserving the legacy admitted-now contract.
Queued admission order is no longer FIFO: a :class:`Scheduler` orders by
priority class with deadline-aware tie-breaks and a one-bucket aging rule
(starvation bound), and owns the head-of-line stall state so paged
backpressure survives across ``serve()`` calls.

**Background serve loop.**  The engine is no longer caller-pumped only:
``engine.start()`` runs the tick on a daemon thread and ``engine.stop()``
drains it, so ``RequestHandle.tokens()`` blocks on a per-handle queue and
streams to real clients without anyone hand-ticking ``serve()``.  The
locking discipline is ONE re-entrant lock around all scheduler + slot +
backend state: every public mutator (``submit``/``cancel``/``preempt``/
``step``/``serve``) takes it, the whole tick runs under it, and the cache
backend asserts it is held before mutating pool state — there is exactly
one writer at any instant, the jit calls themselves are single-threaded,
and the synchronous ``serve(requests)`` path is a thin wrapper over the
same ``_tick()`` so loop-mode output is token-identical to sync output
(pinned).  All timestamps (``submit_ts``/``token_ts``/``deadline``) share
one time base: the injected ``clock`` callable (default
``time.perf_counter``), so a virtual clock makes latency and
deadline-miss accounting fully deterministic (see
``benchmarks/load_harness.py``).

The cache substrate is fully owned by :mod:`repro.serve.backend`: the
engine holds ONE :class:`~repro.serve.backend.CacheBackend` and never
branches on family or substrate — dense slabs, paged block pools, dense
recurrent state, and the hybrid's split substrate are all the same code
path here.  Substrate semantics, in backend terms:

* **dense** — per-slot (max_batch, max_seq, ...) cache rows; a slot
  reserves a full ``max_seq`` row for its whole lifetime.
* **paged** (``EngineConfig(paged=True)``) — admission reserves only
  ``ceil(min(len(prompt) + max_new, max_seq) / block_size)`` blocks (so
  decode can never run out mid-request), freeing a slot just returns its
  blocks to the pool.  When the pool is short, admission backpressures
  until blocks free.
* **split substrate** (hybrid, ``paged=True``) — attention KV leaves in
  the block pool, O(1) SSM state dense, routed structurally per leaf.

**Chunked prefill** (``prefill_chunk=N``): prompts longer than N tokens are
admitted in N-token pieces interleaved with decode ticks — each tick runs
at most ONE chunk of prefill work before the decode step.  Attention
chunks continue the staged KV cache at the write offset; the recurrent
families resume the mamba2 SSD scan from the carried (conv, state), so
chunked and length-bucketed prefill are both token-identical to
whole-prompt prefill.

**Prefix cache** (``prefix_cache=True``): a radix tree over prompt tokens
(``repro.serve.prefix_cache``) remembers what prefill already computed.
Admission matches the longest cached prefix and re-prefills only the
uncached tail — LUNA's capacity-for-computation bet applied to serving.
Warm admissions ride the same staged machinery as chunked prefill — whose
token-identity to whole-prompt prefill is already pinned — so warm output
is token-identical to cold for every family and both scheduler paths.

Sampling draws from per-request PRNG streams (``fold_in(seed_key, rid)``
then per-token step) — a request's sampled tokens are independent of its
slot index, co-tenants, and scheduling, for every sampling mode.

Serving the paper's technique = run with ``--quant luna_*`` so every
projection goes through the LUNA integer path.
"""
from __future__ import annotations

import math
import queue
import threading
import time
from dataclasses import dataclass, field, fields

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_model
from repro.obs import (ITL_BUCKETS, PHASE_BUCKETS, SPEC_REQUEST_BUCKETS,
                       SPEC_WINDOW_BUCKETS, TTFT_BUCKETS, MetricsRegistry,
                       Tracer)
from repro.serve.backend import make_backend
from repro.serve.config import EngineConfig
from repro.serve.paged import ceil_div
from repro.serve.prefix_cache import PrefixCache
from repro.serve.sampling import SamplingConfig, sample
from repro.serve.spec import accept_length


@dataclass(eq=False)
class Request:
    """One generation request.

    ``priority``: scheduler class — higher admits first (e.g. 0 = batch,
    1 = interactive).  ``deadline``: a stamp on the ENGINE CLOCK (the
    ``clock`` callable injected at construction, default
    ``time.perf_counter`` — compute deadlines as ``engine.clock() +
    budget``, NOT ``time.time()``) used as the within-class tie-break
    (earlier = sooner; None = no deadline) and for first-token
    deadline-miss accounting.  ``submit_ts``/``token_ts`` are stamped by
    the engine on the same clock — TTFT is ``token_ts[0] - submit_ts``,
    ITL the consecutive ``token_ts`` gaps; one time base means every
    latency and deadline quantity is directly comparable (and
    deterministic under a virtual clock).
    ``eq=False``: a request is an identity (the engine keys streaming
    callbacks on the object itself, so rid reuse can never cross streams).
    """
    rid: int
    prompt: list[int]
    max_new: int = 16
    priority: int = 0
    deadline: float | None = None
    out: list[int] = field(default_factory=list)
    done: bool = False
    cancelled: bool = False
    submit_ts: float | None = field(default=None, repr=False)
    token_ts: list[float] = field(default_factory=list, repr=False)
    # engine-internal: the submit trace event / submitted counter fired
    # (submit_ts alone can't carry this — harnesses pre-pin arrival
    # stamps, and a backpressured submit() retry must not double-count)
    _submit_seen: bool = field(default=False, repr=False)
    # engine-internal speculative-decoding tallies, observed into the
    # per-request histograms at retirement (spec mode only)
    _spec_accepted: int = field(default=0, repr=False)
    _spec_rejected: int = field(default=0, repr=False)


#: end-of-stream sentinel pushed onto every subscribed token queue at
#: retirement (completion OR cancellation) — queue consumers never poll.
_STREAM_DONE = object()


class RequestHandle:
    """Live view of one submitted request.

    * truthiness — ``bool(handle)`` is the legacy ``submit()`` contract:
      True iff the request was admitted immediately.  False = backpressure:
      with the background loop running the request IS left queued on the
      scheduler (the loop admits it when capacity frees); without the loop
      it is NOT queued — retry, or hand it to ``serve()``.
    * streaming — :meth:`tokens` yields tokens incrementally.  While the
      background loop runs it BLOCKS on a per-handle queue (each emitted
      token is pushed under the engine lock, so no token is missed or
      duplicated); without the loop it drives the engine one tick at a
      time between yields, exactly as before.  An ``on_token`` callback
      registered at ``submit()`` fires synchronously per emitted token.
      The streamed sequence is exactly ``req.out`` (pinned in tests).
    * :meth:`cancel` — releases the request's slot, blocks and staged
      state wherever it currently is in the lifecycle; safe from any
      thread.
    """

    def __init__(self, engine: "Engine", req: Request, on_token=None):
        self._engine = engine
        self.req = req
        self._on_token = on_token
        self._admitted = False

    def __bool__(self) -> bool:
        return self._admitted

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def out(self) -> list[int]:
        return self.req.out

    @property
    def done(self) -> bool:
        return self.req.done

    @property
    def cancelled(self) -> bool:
        return self.req.cancelled

    def cancel(self) -> bool:
        """Stop the request and release its resources; True unless it had
        already finished.  Covers every lifecycle stage — queued, staged
        mid-chunked-prefill, actively decoding, or never admitted (the
        engine then just closes the request out)."""
        return self._engine.cancel(self.req)

    def tokens(self):
        """Generator of this request's tokens, in emission order, ending
        when the request completes (or is cancelled).

        With the background loop running this blocks on the handle's
        stream queue — the loop thread does all engine work and each
        ``get`` wakes exactly when the next token (or the end-of-stream
        sentinel) lands.  Without the loop it drives the engine one tick
        at a time while waiting, and an un-admitted handle re-attempts
        admission between ticks (the legacy contract).  The two modes
        compose: the generator re-checks ``engine.running`` on every wait
        so a loop started or stopped mid-stream is picked up."""
        eng = self._engine
        q = eng._subscribe(self.req)
        while True:
            try:
                tok = q.get_nowait()
            except queue.Empty:
                tok = None
            if tok is _STREAM_DONE:
                return
            if tok is not None:
                yield tok
                continue
            if eng.running:
                # loop mode: block until the loop delivers (bounded wait so
                # a stop(drain=False) mid-stream falls back to sync mode)
                try:
                    tok = q.get(timeout=0.1)
                except queue.Empty:
                    continue
                if tok is _STREAM_DONE:
                    return
                yield tok
                continue
            # sync mode: the caller's thread is the engine
            if self.req.done:
                continue        # sentinel is already in the queue
            if not self._admitted:
                self._admitted = eng._admit_handle(self)
                if not self._admitted and eng.idle:
                    raise RuntimeError(
                        f"request {self.req.rid} cannot be admitted on an "
                        "idle engine (capacity permanently short?)")
            if not self.req.done:
                eng.step()


@dataclass(eq=False)
class _QueueEntry:
    """Scheduler bookkeeping for one queued request.  ``passed`` counts
    admissions that went to OTHER requests while this one waited;
    ``enqueue_ts`` is stamped on the scheduler's clock (the engine's
    injected clock) so queue-wait time shares the single time base."""
    req: Request
    arrival: int
    passed: int = 0
    enqueue_ts: float = 0.0


class Scheduler:
    """Priority-class admission queue with deadline tie-breaks, one-bucket
    aging, and the persistent head-of-line stall state.

    Ordering: highest *effective* priority class first; within a class,
    aged entries first (by arrival), then earliest deadline, then arrival.
    Effective priority = ``req.priority``, plus ONE bucket once the entry
    has been passed over ``starvation_bound`` times.

    Documented bounds (pinned by the scheduler property tests):

    * **priority inversion <= one bucket** — at every admission, any
      still-queued request's ``priority`` exceeds the admitted request's
      ``priority`` by at most 1 (aging adds at most one bucket, and the
      scheduler always picks a maximal effective class).
    * **starvation bound** — under priorities spanning two adjacent
      classes, a queued request is passed over at most ``starvation_bound``
      times by higher-priority work plus once per earlier-arrived request
      (aged entries outrank every unaged and every later-arrived aged
      entry of their class, so new arrivals can never leapfrog them).

    The stall state (per-rid ``free_capacity`` at the last failed
    reservation) lives HERE, not in ``serve()``'s locals, so paged
    backpressure survives across ``serve()`` calls and ``submit()`` uses
    the same logic — a backpressured request retries only after capacity
    actually grew, instead of re-walking the radix tree (and churning
    shared-block refcounts) on every attempt; stalls are tracked per rid
    so concurrently backpressured pollers cannot thrash each other's
    record.
    """

    def __init__(self, starvation_bound: int = 8, clock=None):
        self.starvation_bound = starvation_bound
        self.clock = clock if clock is not None else time.perf_counter
        self._queue: list[_QueueEntry] = []
        self._arrivals = 0
        self._stalls: dict[int, int] = {}

    @property
    def pending(self) -> int:
        return len(self._queue)

    def push(self, req: Request) -> None:
        self._queue.append(_QueueEntry(req, self._arrivals,
                                       enqueue_ts=self.clock()))
        self._arrivals += 1

    def queued(self, req: Request) -> bool:
        """True if ``req`` (by object identity) is currently queued."""
        return any(e.req is req for e in self._queue)

    def aged(self, e: _QueueEntry) -> bool:
        return e.passed >= self.starvation_bound

    def effective_priority(self, e: _QueueEntry) -> int:
        """Base priority, plus at most ONE aging bucket (this cap is what
        bounds priority inversion to one bucket)."""
        return e.req.priority + (1 if self.aged(e) else 0)

    def _key(self, e: _QueueEntry):
        if self.aged(e):
            return (-self.effective_priority(e), 0, float(e.arrival),
                    e.arrival)
        dl = e.req.deadline if e.req.deadline is not None else math.inf
        return (-self.effective_priority(e), 1, dl, e.arrival)

    def select(self) -> _QueueEntry | None:
        """The entry the next admission should take (queue unchanged)."""
        if not self._queue:
            return None
        return min(self._queue, key=self._key)

    def commit(self, entry: _QueueEntry) -> None:
        """``entry`` was admitted: remove it and age everyone it passed."""
        self._queue.remove(entry)
        self.age_all()

    def age_all(self) -> None:
        """An admission went to someone not in the queue (or just removed
        from it): every waiting entry was passed over once.  Direct
        ``submit()`` admissions call this too, so the starvation bound
        holds engine-wide, not just for queue-internal admissions."""
        for e in self._queue:
            e.passed += 1

    def remove(self, req: Request) -> bool:
        """Drop a queued request BY OBJECT IDENTITY (cancellation before
        admission, or a direct admission claiming its own stale entry) —
        rid matching could tear down an unrelated request reusing the
        number."""
        for e in self._queue:
            if e.req is req:
                self._queue.remove(e)
                return True
        return False

    def drop(self, entry: _QueueEntry) -> None:
        """Evict one entry without aging anyone (no admission happened)."""
        self._queue.remove(entry)

    # --- head-of-line stall bookkeeping ---------------------------------
    _MAX_STALLS = 128          # bound on abandoned-rid stall records

    def stalled(self, rid: int, capacity: int, need: int) -> bool:
        """True while ``rid``'s last reservation failure still stands: the
        retry wants at least as much capacity as the failed attempt and
        capacity has not grown past what it failed at.  A smaller request
        reusing the rid is NOT gated — the record is per failed demand,
        not per name."""
        rec = self._stalls.get(rid)
        return rec is not None and need >= rec[1] and capacity <= rec[0]

    def note_stall(self, rid: int, capacity: int, need: int) -> None:
        self._stalls[rid] = (capacity, need)
        while len(self._stalls) > self._MAX_STALLS:
            self._stalls.pop(next(iter(self._stalls)))

    def clear_stall(self, rid: int | None = None) -> None:
        if rid is None:
            self._stalls.clear()
        else:
            self._stalls.pop(rid, None)


@dataclass(eq=False)
class _ChunkedPrefill:
    """A staged admission in flight: its reserved slot + staged cache rows
    (long chunked prompts, warm prefix-cache hits, and cold recurrent
    admissions that capture a mid-prompt state snapshot all ride this).
    ``eq=False``: identity semantics — field-wise ``==`` on staged jax
    pytrees is both meaningless and a crash."""
    req: Request
    slot: int
    staging: object        # dense (1, stage_len) cache tree
    consumed: int = 0      # prompt tokens already prefilled (or reused)
    capture_at: int | None = None   # grid boundary to snapshot state at
    captured: object | None = None  # the snapshot, once captured
    scatter_table: object | None = None  # COW redirect for the final scatter


@dataclass
class EngineMetrics:
    """Wall-clock + token accounting split by phase: the VALUE type.

    A plain snapshot — ``Engine.metrics`` is an :class:`EngineMetricsView`
    over the engine's :class:`~repro.obs.registry.MetricsRegistry` that
    reads and writes these same fields live; ``view.snapshot()`` (and
    ``since()``) return instances of this dataclass.  The field set,
    ``since()``, and ``summary()`` contracts are pinned in
    ``tests/test_obs.py``."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0      # prompt tokens pushed through prefill
    decode_tokens: int = 0       # tokens emitted by decode ticks
    prefill_calls: int = 0       # jit prefill invocations (bucket or chunk)
    prefill_chunks: int = 0      # chunked-admission pieces among those
    ticks: int = 0
    occupancy_sum: int = 0       # sum over ticks of active slots
    prefix_hits: int = 0         # admissions seeded from the prefix cache
    prefix_tokens_reused: int = 0   # prompt tokens NOT re-prefilled
    cache_evictions: int = 0     # prefix-cache nodes evicted (LRU)
    cancelled: int = 0           # requests cancelled mid-lifecycle
    preemptions: int = 0         # active requests kicked back to the queue
    deadline_hits: int = 0       # first token on or before req.deadline
    deadline_misses: int = 0     # first token after req.deadline
    spec_ticks: int = 0          # speculative draft->verify ticks run
    spec_drafted: int = 0        # draft tokens proposed across spec ticks
    spec_accepted: int = 0       # draft tokens the verifier accepted
    spec_rejected: int = 0       # draft tokens the verifier rejected

    def since(self, start: "EngineMetrics") -> "EngineMetrics":
        """Per-call delta: these counters minus a ``start`` snapshot (the
        engine-lifetime metrics keep accumulating across serve() calls)."""
        return EngineMetrics(**{
            f.name: getattr(self, f.name) - getattr(start, f.name)
            for f in fields(self)})

    def summary(self, max_batch: int) -> dict:
        d = {
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": self.prefill_chunks,
            "ticks": self.ticks,
            # tok/s is 0.0 when NO tokens moved: an empty run divides 0
            # tokens by near-zero wall time, and 0/eps reporting absurd
            # throughputs is worse than an honest zero
            "prefill_tok_s": (self.prefill_tokens
                              / max(self.prefill_s, 1e-9)
                              if self.prefill_tokens else 0.0),
            "decode_tok_s": (self.decode_tokens / max(self.decode_s, 1e-9)
                             if self.decode_tokens else 0.0),
            "occupancy": (self.occupancy_sum / (self.ticks * max_batch)
                          if self.ticks else 0.0),
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            "cache_evictions": self.cache_evictions,
            "cancelled": self.cancelled,
            "preemptions": self.preemptions,
            "deadline_hits": self.deadline_hits,
            "deadline_misses": self.deadline_misses,
            "spec_ticks": self.spec_ticks,
            "spec_drafted": self.spec_drafted,
            "spec_accepted": self.spec_accepted,
            "spec_rejected": self.spec_rejected,
            "spec_acceptance": (self.spec_accepted / self.spec_drafted
                                if self.spec_drafted else 0.0),
        }
        return d


#: EngineMetrics field -> (registry metric name, help).  The registry is
#: the single source of truth; the view below is the dataclass-shaped
#: facade engine code and tests read/write.
_ENGINE_COUNTERS = {
    "prefill_s": ("engine_prefill_seconds_total",
                  "wall seconds inside prefill jit calls"),
    "decode_s": ("engine_decode_seconds_total",
                 "wall seconds inside decode jit calls"),
    "prefill_tokens": ("engine_prefill_tokens_total",
                       "prompt tokens pushed through prefill"),
    "decode_tokens": ("engine_decode_tokens_total",
                      "tokens emitted by decode ticks"),
    "prefill_calls": ("engine_prefill_calls_total",
                      "jit prefill invocations (bucket or chunk)"),
    "prefill_chunks": ("engine_prefill_chunks_total",
                       "chunked-admission prefill pieces"),
    "ticks": ("engine_ticks_total", "engine ticks run"),
    "occupancy_sum": ("engine_occupancy_slots_total",
                      "sum over ticks of active slots"),
    "prefix_hits": ("engine_prefix_hits_total",
                    "admissions seeded from the prefix cache"),
    "prefix_tokens_reused": ("engine_prefix_tokens_reused_total",
                             "prompt tokens not re-prefilled"),
    "cache_evictions": ("engine_prefix_cache_evictions_total",
                        "prefix-cache nodes evicted (LRU)"),
    "cancelled": ("engine_requests_cancelled_total",
                  "requests cancelled mid-lifecycle"),
    "preemptions": ("engine_preemptions_total",
                    "active requests kicked back to the queue"),
    "deadline_hits": ("engine_deadline_hits_total",
                      "first token on or before the request deadline"),
    "deadline_misses": ("engine_deadline_misses_total",
                        "first token after the request deadline"),
    "spec_ticks": ("engine_spec_ticks_total",
                   "speculative draft->verify ticks run"),
    "spec_drafted": ("engine_spec_drafted_tokens_total",
                     "draft tokens proposed across speculative ticks"),
    "spec_accepted": ("engine_spec_accepted_tokens_total",
                      "draft tokens the verifier accepted"),
    "spec_rejected": ("engine_spec_rejected_tokens_total",
                      "draft tokens the verifier rejected"),
}


class EngineMetricsView:
    """Live :class:`EngineMetrics` facade over a metrics registry.

    Attribute reads return the registry counter's current value and
    attribute writes set it (``engine.metrics.ticks += 1`` and the
    bench's counter resets both work unchanged), so the registry is the
    single source of truth while every historical ``engine.metrics``
    call site keeps its contract.  ``snapshot()`` materializes a plain
    :class:`EngineMetrics`; ``since()``/``summary()`` delegate to it.
    """

    __slots__ = ("_counters",)

    def __init__(self, registry):
        object.__setattr__(self, "_counters", {
            f: registry.counter(name, help)
            for f, (name, help) in _ENGINE_COUNTERS.items()})

    def __getattr__(self, name):
        try:
            c = object.__getattribute__(self, "_counters")[name]
        except KeyError:
            raise AttributeError(name) from None
        return c.value()

    def __setattr__(self, name, value):
        counters = object.__getattribute__(self, "_counters")
        if name not in counters:
            raise AttributeError(
                f"EngineMetricsView has no metric field {name!r}")
        counters[name].set(value)

    def snapshot(self) -> EngineMetrics:
        return EngineMetrics(**{f.name: getattr(self, f.name)
                                for f in fields(EngineMetrics)})

    def since(self, start: EngineMetrics) -> EngineMetrics:
        return self.snapshot().since(start)

    def summary(self, max_batch: int) -> dict:
        return self.snapshot().summary(max_batch)


class Engine:
    def __init__(self, cfg, params, config: EngineConfig | None = None,
                 *, clock=None):
        """``clock``: the engine's single time base — a zero-arg callable
        returning monotonic seconds (default ``time.perf_counter``).
        Every ``submit_ts``/``token_ts`` stamp, metrics wall-clock
        interval, and deadline comparison goes through it, so injecting a
        virtual clock makes latency + deadline accounting deterministic
        (the load harness does exactly that)."""
        if config is None:
            config = EngineConfig()
        config.validate(cfg.family)
        if config.quant is not None and getattr(cfg, "quant", None) is not \
                None and cfg.quant.mode != "bf16":
            raise ValueError(
                f"EngineConfig(quant={config.quant!r}) freezes decode "
                f"weights to 4-bit; combining it with model-level "
                f"quant mode {cfg.quant.mode!r} would quantize twice — "
                "pick one")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.config = config
        self.max_batch = config.max_batch
        self.max_seq = config.max_seq
        self.sampling = config.sampling or SamplingConfig()
        self.prefill_bucket = config.prefill_bucket
        self.prefill_chunk = config.prefill_chunk
        self.backend = make_backend(self.model, cfg.family, config)
        self.caches = self.backend.caches
        # decode weights are backend-owned state: the full-precision tree
        # itself under quant=None (token-identity), a frozen 4-bit tree
        # under quant="lut4"/"int4" (affine) or "nf4"/"nf4p" (NF4 codebook
        # + D&C residual correction) — prefill always uses self.params
        self.decode_params = self.backend.prepare_decode_params(
            params, config.quant)
        self.prefix_cache = None
        if config.prefix_cache:
            self.prefix_cache = PrefixCache(
                max_nodes=config.prefix_cache_nodes,
                **self.backend.prefix_cache_kwargs())
            # recurrent snapshots are captured on this boundary grid;
            # paged payloads must land on whole blocks
            self._capture_grid = self.backend.capture_grid(
                config.prefill_bucket)
        self._evictions_seen = 0
        self.positions = np.zeros(config.max_batch, np.int32)
        self.key = jax.random.PRNGKey(config.seed)
        self.active: dict[int, Request] = {}
        self.slots: list[Request | None] = [None] * config.max_batch
        self._chunked: list[_ChunkedPrefill] = []
        self._admitting = False        # _admit in flight (emit window)
        self._callbacks: dict[Request, list] = {}
        self._streams: dict[Request, list[queue.SimpleQueue]] = {}
        self.clock = clock if clock is not None else time.perf_counter
        # ONE re-entrant lock guards scheduler + slot + backend state:
        # every public mutator and the whole tick run under it
        self._lock = threading.RLock()
        self.backend.bind_lock(self._lock)
        self._loop_thread: threading.Thread | None = None
        self._loop_stop = threading.Event()
        self._loop_wake = threading.Event()
        self._drain_on_stop = True
        self.scheduler = Scheduler(config.starvation_bound,
                                   clock=self.clock)
        # observability: ONE registry per engine is the source of truth
        # for every counter (self.metrics is a live view over it); the
        # tracer shares self.clock so virtual-clock runs trace
        # deterministically.  Both exist even when tracing is off —
        # disabled tracer events are a cheap early-return.
        self.registry = MetricsRegistry()
        self.metrics = EngineMetricsView(self.registry)
        self.tracer = Tracer(clock=self.clock,
                             capacity=config.trace_buffer,
                             enabled=config.trace)
        self._obs_init(cfg.family, config)
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)
        self._chunk_step = jax.jit(self._chunk_step_impl)
        self._chunk_finish = jax.jit(self._chunk_finish_impl)
        self._seed_gather = jax.jit(self.backend.gather_staging)
        # speculative decoding: the proposer drafts, _verify scores the
        # whole (B, spec_k+1) window at the DECODE precision in one call,
        # _spec_commit re-runs a partial-accept window on recurrent
        # substrates, _draft is the self-speculation step over the pruned
        # nf4p LUT tree (see repro.serve.spec)
        self._spec = None
        if config.spec is not None:
            from repro.core.quant import (SPEC_DRAFT_QUANT,
                                          quantize_draft_params)
            from repro.serve.spec import make_proposer
            if config.spec == "self_lut":
                self.draft_params = (
                    self.decode_params
                    if config.quant == SPEC_DRAFT_QUANT
                    else quantize_draft_params(params))
                self._draft = jax.jit(self._draft_impl)
            self._spec = make_proposer(config.spec, self)
            self._verify = jax.jit(self._verify_impl)
            self._spec_commit = jax.jit(self._spec_commit_impl)

    # --- observability ---------------------------------------------------
    def _obs_init(self, family: str, config: EngineConfig):
        """Register the engine's non-EngineMetrics instruments: latency
        histograms, lifecycle counters, level gauges, and the static
        ``engine_info`` identity series."""
        reg = self.registry
        self._h_ttft = reg.histogram(
            "engine_ttft_seconds",
            "time from submit to first emitted token",
            ("priority",), buckets=TTFT_BUCKETS)
        self._h_itl = reg.histogram(
            "engine_itl_seconds",
            "latency between consecutive emitted tokens",
            ("priority",), buckets=ITL_BUCKETS)
        self._h_phase = reg.histogram(
            "engine_tick_phase_seconds",
            "wall seconds per engine phase per tick",
            ("phase",), buckets=PHASE_BUCKETS)
        self._h_spec_window = reg.histogram(
            "engine_spec_accepted_per_window",
            "accepted draft tokens per speculative verify window",
            ("proposer",), buckets=SPEC_WINDOW_BUCKETS)
        self._h_spec_request = reg.histogram(
            "engine_spec_tokens_per_request",
            "accepted/rejected draft tokens per retired request",
            ("kind",), buckets=SPEC_REQUEST_BUCKETS)
        self._c_submitted = reg.counter(
            "engine_requests_submitted_total",
            "requests submitted (first submission only)", ("priority",))
        self._c_finished = reg.counter(
            "engine_requests_finished_total",
            "requests retired (completed or cancelled)")
        self._c_prefix_lookups = reg.counter(
            "engine_prefix_lookups_total",
            "prefix-cache lookups by result", ("result",))
        self._g_queue = reg.gauge(
            "engine_queue_depth", "requests queued on the scheduler")
        self._g_active = reg.gauge(
            "engine_active_slots", "slots actively decoding")
        self._g_staged = reg.gauge(
            "engine_staged_admissions",
            "staged (chunked / warm-prefix) admissions in flight")
        self._g_free = reg.gauge(
            "engine_pool_free_capacity",
            "backend free capacity (dense: slots; paged: blocks)")
        reg.gauge(
            "engine_info",
            "static engine identity (value is always 1)",
            ("family", "quant", "paged", "spec"),
        ).set(1, family=family, quant=config.quant or "bf16",
              paged=str(bool(config.paged)).lower(),
              spec=config.spec or "off")
        self._update_gauges()

    def _update_gauges(self):
        """Refresh the level gauges; called at every queue/slot/pool
        transition (all under the engine lock)."""
        self._g_queue.set(self.scheduler.pending)
        self._g_active.set(len(self.active))
        self._g_staged.set(len(self._chunked))
        self._g_free.set(self.backend.free_capacity)

    def _note_submit(self, req: Request):
        """Once-only submit accounting: the counter bumps and the trace
        event fires the FIRST time the engine sees the request, stamped
        at its (possibly harness-pinned) ``submit_ts``."""
        if not req._submit_seen:
            req._submit_seen = True
            self._c_submitted.add(priority=str(req.priority))
            self.tracer.event("submit", rid=req.rid, ts=req.submit_ts,
                              priority=req.priority)

    # --- substrate views (compat surface; the logic lives in backend) ---
    @property
    def paged(self) -> bool:
        return self.backend.paged

    @property
    def allocator(self):
        return getattr(self.backend, "allocator", None)

    @property
    def block_tables(self):
        return getattr(self.backend, "block_tables", None)

    @property
    def idle(self) -> bool:
        """Nothing queued, staged, or decoding."""
        return not (self.active or self._chunked or self.scheduler.pending)

    # --- jit bodies -----------------------------------------------------
    def _prefill_impl(self, params, tokens, slab, last_pos, slots, tables,
                      rids, key):
        """Prefill a (k, L) token bucket against fresh caches, scatter the
        rows into the slab (dense leaves: at slot ids; pool leaves: at
        block tables), sample each row's first token from its own stream."""
        k = tokens.shape[0]
        fresh = self.backend.fresh(k)
        logits, rows = self.model.prefill(params, tokens, fresh,
                                          last_pos=last_pos)
        new_slab = self.backend.scatter(slab, rows, slots, tables)
        toks = sample(logits[:, 0], key, self.sampling, rids=rids,
                      steps=jnp.zeros_like(rids))
        return toks, new_slab

    def _decode_impl(self, params, tokens, caches, positions, tables, rids,
                     steps, key):
        logits, new_caches = self.model.decode_step(
            params, tokens, caches, positions, tables=tables)
        toks = sample(logits[:, 0], key, self.sampling, rids=rids,
                      steps=steps)
        return toks, new_caches

    def _verify_impl(self, params, tokens, caches, positions, tables,
                     n_valid, last_pos):
        """Speculative verify: score the whole (B, W) window in ONE call.
        ``argmax(logits[:, i])`` is the greedy token after column ``i`` —
        bitwise the same reduction the non-speculative tick applies to its
        1-wide logits (spec mode is greedy-only by config), which is what
        pins spec output token-identical to plain decode."""
        logits, new_caches = self.model.decode_window(
            params, tokens, caches, positions, tables=tables,
            n_valid=n_valid, last_pos=last_pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_caches

    def _spec_commit_impl(self, params, tokens, caches, positions, tables,
                          n_valid, last_pos):
        """Partial-accept commit on recurrent substrates: re-run the SAME
        window from the PRE-verify cache tree with the SSD scan masked at
        the accept boundary (``last_pos`` = accepted count, -1 for
        inactive rows) so the carried state ingests exactly the accepted
        tokens and nothing after them.  Logits are discarded — the
        verifier already fixed the emitted tokens."""
        _, new_caches = self.model.decode_window(
            params, tokens, caches, positions, tables=tables,
            n_valid=n_valid, last_pos=last_pos)
        return new_caches

    def _draft_impl(self, params, tokens, caches, positions, tables):
        """One greedy self-speculation step over the pruned-LUT draft
        weights against a throwaway functional cache copy."""
        logits, new_caches = self.model.decode_step(
            params, tokens, caches, positions, tables=tables)
        return (jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32),
                new_caches)

    def _chunk_step_impl(self, params, tokens, staging, offset):
        """One mid-prompt chunk: continue the staged (1, stage_len) cache
        at ``offset`` (the trailing-logits matmul is 1 row — negligible)."""
        _, staging = self.model.prefill(params, tokens, staging,
                                        cache_index=offset)
        return staging

    def _chunk_finish_impl(self, params, tokens, staging, offset, last_pos,
                           slab, slots, tables, rid, key):
        """Final chunk: finish the staged row, sample its first token, and
        scatter the whole staged cache into the slab/pool in one go.  The
        finished staging tree is also returned — the prefix cache snapshots
        its recurrent leaves (state at the full prompt boundary)."""
        logits, staging = self.model.prefill(params, tokens, staging,
                                             last_pos=last_pos,
                                             cache_index=offset)
        new_slab = self.backend.scatter(slab, staging, slots, tables)
        tok = sample(logits[:, 0], key, self.sampling, rids=rid,
                     steps=jnp.zeros_like(rid))
        return tok, new_slab, staging

    # --- admission ------------------------------------------------------
    def _validate(self, req: Request):
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new must be >= 1 (prefill always "
                f"samples one token), got {req.max_new}")
        if not (0 < len(req.prompt) <= self.max_seq - 1):
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} not in "
                f"[1, max_seq-1={self.max_seq - 1}]")
        self.backend.validate_request(req.rid, len(req.prompt), req.max_new)

    def _reserve(self, req: Request, slot: int, hit=None) -> bool:
        """Claim the request's lifetime substrate capacity up front (paged:
        its block budget; a prefix hit's shared blocks are ref'd
        copy-on-write and only the tail is allocated privately).  False =
        backpressure; dense substrates always succeed."""
        shared = list(hit.blocks) if hit is not None else None
        return self.backend.reserve(slot, len(req.prompt), req.max_new,
                                    shared, on_short=self._on_pool_short)

    def _on_pool_short(self, need: int):
        """Pool pressure hook: let the prefix cache evict LRU unreferenced
        nodes before the reservation backpressures."""
        if self.prefix_cache is not None:
            self.prefix_cache.evict_for(need)
            self._note_evictions()

    def _note_evictions(self):
        """Fold the prefix cache's lifetime eviction count into the
        monotonic engine metrics."""
        if self.prefix_cache is not None:
            d = self.prefix_cache.evictions - self._evictions_seen
            self._evictions_seen = self.prefix_cache.evictions
            self.metrics.cache_evictions += d

    def _free_slot(self, slot: int):
        self.slots[slot] = None
        self.positions[slot] = 0
        self.backend.free_slot(slot)

    def _chunkable(self, prompt_len: int) -> bool:
        return (self.prefill_chunk is not None
                and prompt_len > self.prefill_chunk)

    # --- token emission / retirement ------------------------------------
    def _emit(self, req: Request, tok: int):
        """Append one generated token: the single emission point — output
        list, latency stamp, deadline accounting, stream queues, and
        streaming callbacks all fan out from here."""
        req.out.append(tok)
        ts = self.clock()
        req.token_ts.append(ts)
        if len(req.out) == 1:
            if req.submit_ts is not None:
                self._h_ttft.observe(ts - req.submit_ts,
                                     priority=str(req.priority))
            self.tracer.event("first_token", rid=req.rid, ts=ts)
            if req.deadline is not None:
                if ts > req.deadline:
                    self.metrics.deadline_misses += 1
                else:
                    self.metrics.deadline_hits += 1
        else:
            self._h_itl.observe(ts - req.token_ts[-2],
                                priority=str(req.priority))
            self.tracer.event("token", rid=req.rid, ts=ts)
        for q in self._streams.get(req, ()):
            q.put(tok)
        for cb in tuple(self._callbacks.get(req, ())):
            cb(tok)

    def _retire(self, req: Request):
        if not req.done:
            # _retire can run twice for a request cancelled mid-admission
            # (cancel() retires it, then the admission path retires again
            # on seeing req.done) — the guard keeps finish single-shot
            self.tracer.event("finish", rid=req.rid, tokens=len(req.out),
                              cancelled=req.cancelled)
            self._c_finished.add()
            if self._spec is not None:
                self._h_spec_request.observe(float(req._spec_accepted),
                                             kind="accepted")
                self._h_spec_request.observe(float(req._spec_rejected),
                                             kind="rejected")
        req.done = True
        self._callbacks.pop(req, None)
        for q in self._streams.pop(req, ()):
            q.put(_STREAM_DONE)

    def _subscribe(self, req: Request) -> queue.SimpleQueue:
        """Open a token stream over ``req``: a fresh queue preloaded (under
        the lock) with everything already emitted, then fed by ``_emit``
        and closed with the sentinel by ``_retire`` — so a late subscriber
        replays the backlog and no token is ever missed or duplicated."""
        with self._lock:
            q = queue.SimpleQueue()
            for tok in req.out:
                q.put(tok)
            if req.done:
                q.put(_STREAM_DONE)
            else:
                self._streams.setdefault(req, []).append(q)
            return q

    # --- prefix cache ---------------------------------------------------
    def _match_prefix(self, req: Request):
        """Longest cached prefix usable for this admission (None = cold).
        At least one tail token must still run through prefill to produce
        the last-position logits, hence the ``len - 1`` cap."""
        if self.prefix_cache is None:
            return None
        hit = self.prefix_cache.match(req.prompt,
                                      max_len=len(req.prompt) - 1,
                                      need_state=self.backend.needs_state)
        self._c_prefix_lookups.add(
            result="hit" if hit is not None else "miss")
        return hit

    def _capture_boundary(self, prompt_len: int) -> int:
        """Grid boundary to snapshot recurrent state at (0 = none)."""
        return (prompt_len // self._capture_grid) * self._capture_grid

    def _route_staged(self, req: Request, hit, lone: bool = True) -> bool:
        """True when the admission must ride the staged path: chunked long
        prompts, every warm hit (the staging row is seeded from the cache),
        and LONE cold recurrent admissions that want a mid-prompt state
        snapshot (the prefill is split at the grid boundary to capture it).
        ``lone=False`` — other cold requests are being admitted this tick —
        keeps cold recurrent prompts on the batched bucket path: concurrent
        cold prefill throughput beats an extra capture boundary (the cache
        still populates from their full-prompt inserts and from warm /
        chunked admissions)."""
        if hit is not None or self._chunkable(len(req.prompt)):
            return True
        if not lone or self.prefix_cache is None \
                or not self.backend.needs_state:
            return False
        cap = self._capture_boundary(len(req.prompt))
        return 0 < cap < len(req.prompt)

    def _seed_staging(self, hit):
        """Build the warm admission's staging row: gather the shared
        blocks' KV into the dense staging leaves (one jit call, compiled
        once) and swap in the recurrent state snapshot.  The tail prefill
        then continues at ``hit.length`` as if the first chunks had just
        run."""
        if hit.blocks:
            tbl = jnp.asarray(self.backend.staging_table(hit.blocks))
            staging = self._seed_gather(self.caches, tbl)
        else:
            staging = self.backend.fresh(1)
        if hit.state is not None:
            staging = self.backend.seed_snapshot(staging, hit.state)
        return staging

    def _insert_boundary(self, prompt: list[int], slot: int, state):
        """Cache one finished-prefill boundary: the backend's
        ``prefix_payload`` is THE per-family storage policy (ssm: state
        snapshot only; attention: whole pool blocks; hybrid: both halves at
        a block-aligned boundary)."""
        payload = self.backend.prefix_payload(prompt, slot, state)
        if payload is None:
            return
        tokens, blocks, state = payload
        self.prefix_cache.insert(tokens, blocks=blocks, state=state)

    def _prefix_insert_from_slot(self, req: Request, slot: int):
        """Cold batched admission: cache the freshly-prefilled prefix —
        state (if the substrate carries one) sliced from the slot's cache
        row at the full prompt boundary."""
        if self.prefix_cache is None:
            return
        state = self.backend.snapshot(self.caches, slot)
        self._insert_boundary(req.prompt, slot, state)
        self._note_evictions()

    def _finish_prefix_insert(self, cp: _ChunkedPrefill, staged_out):
        """Staged admission done: insert the mid-prompt capture (if one was
        taken) and the full-prompt boundary into the radix tree."""
        if self.prefix_cache is None:
            return
        req, slot = cp.req, cp.slot
        if cp.captured is not None:
            self._insert_boundary(req.prompt[:cp.capture_at], slot,
                                  cp.captured)
        state = self.backend.snapshot(staged_out, 0)
        self._insert_boundary(req.prompt, slot, state)
        self._note_evictions()

    # --- public API -----------------------------------------------------
    def submit(self, req: Request, *, on_token=None) -> RequestHandle:
        """Submit one request; thread-safe.  The returned handle is truthy
        iff the request was admitted immediately.  On backpressure (no
        free slot, or — paged — the block pool is short): with the
        background loop running the request is left QUEUED on the
        scheduler and the loop admits it in priority order as capacity
        frees (the falsy handle still streams); without the loop it is
        NOT queued — retry, or hand it to ``serve()``.  Long prompts under
        ``prefill_chunk`` start a chunked admission that ``step()``
        advances one chunk per tick.  ``on_token`` fires synchronously
        for every emitted token."""
        with self._lock:
            self._validate(req)
            if req.submit_ts is None:
                req.submit_ts = self.clock()
            self._note_submit(req)
            handle = RequestHandle(self, req, on_token=on_token)
            if self.running:
                # loop mode: register the callback for the whole queued
                # lifetime (the loop admits later, off this thread) and
                # fall back to the scheduler instead of dropping the
                # request on backpressure
                if on_token is not None:
                    cbs = self._callbacks.setdefault(req, [])
                    if on_token not in cbs:
                        cbs.append(on_token)
                handle._admitted = self._try_admit(req)
                if not handle._admitted and not req.done \
                        and not self.scheduler.queued(req):
                    self.scheduler.push(req)
                    self.tracer.event("queue", rid=req.rid)
            else:
                handle._admitted = self._admit_handle(handle)
            self._update_gauges()
        self._loop_wake.set()
        return handle

    def _admit_handle(self, handle: RequestHandle) -> bool:
        """Admission attempt for a handle: the streaming callback is live
        exactly while the request is admitted — registered before the
        attempt (the prefill emits the first token synchronously) and
        unregistered again on failure, so an abandoned falsy handle leaks
        nothing onto later requests."""
        with self._lock:
            req, cb = handle.req, handle._on_token
            if req.done:
                return False              # finished/cancelled: nothing to
            if cb is not None:            # admit, nothing to register
                cbs = self._callbacks.setdefault(req, [])
                if cb not in cbs:         # idempotent: a backpressured
                    cbs.append(cb)        # submit retried with the same
                # callback must not double-fire per token
            admitted = self._try_admit(req)
            if not admitted and cb is not None:
                cbs = self._callbacks.get(req, [])
                if cb in cbs:
                    cbs.remove(cb)
                if not cbs:
                    self._callbacks.pop(req, None)
            return admitted

    def _try_admit(self, req: Request) -> bool:
        """One admission attempt, sharing the scheduler's state.

        * Stall bookkeeping: a request whose reservation already failed
          retries only once capacity has actually grown (no radix-tree
          re-walk, no refcount churn on every poll).
        * Queue fairness: a direct admission must not leapfrog queued work
          of equal-or-higher effective priority (the scheduler's
          starvation/inversion bounds hold engine-wide), ages the queue
          when it does win, and claims the request's own stale queue entry
          so a request can never be admitted twice."""
        if req.done:
            return False
        if self.active.get(req.rid) is req or \
                any(cp.req is req for cp in self._chunked):
            return True                       # already admitted
        self._check_rid_free(req)
        if self._admitting:
            # re-entrant submit from an on_token callback while _admit is
            # mid-flight: the in-flight request's slot is not recorded yet
            # and must not be stolen — report backpressure instead
            return False
        free = [s for s, r in enumerate(self.slots) if r is None]
        if not free:
            return False
        head = self.scheduler.select()
        if head is not None and head.req is not req and \
                self.scheduler.effective_priority(head) >= req.priority:
            # queued work outranks (or ties) this direct submit: let the
            # next tick's _admit_pending serve the queue first
            return False
        need = self.backend.reservation_need(len(req.prompt), req.max_new)
        if self.scheduler.stalled(req.rid, self.backend.free_capacity,
                                  need):
            return False
        hit = self._match_prefix(req)
        if not self._reserve(req, free[0], hit):
            self.scheduler.note_stall(req.rid, self.backend.free_capacity,
                                      need)
            return False
        self.scheduler.clear_stall(req.rid)
        self.scheduler.remove(req)            # claim our own stale entry
        self.scheduler.age_all()
        if self._route_staged(req, hit):
            self._start_staged(req, free[0], hit)
        else:
            self._admit([req], free[:1])
        return True

    def _check_rid_free(self, req: Request):
        """Rids must be unique among LIVE requests (the active dict, the
        sampling streams, and the metrics all key on them): admitting a
        different object under a live rid corrupts both streams."""
        if req.rid in self.active or \
                any(cp.req.rid == req.rid for cp in self._chunked):
            raise ValueError(
                f"rid {req.rid} is already in flight for a different "
                "request — rids must be unique among live requests")

    def cancel(self, req: Request) -> bool:
        """Cancel wherever the request is in its lifecycle: drop it from
        the scheduler queue, abort a mid-flight staged admission (staged
        cache rows and snapshot dropped, reserved blocks — including
        copy-on-write shared prefix refs — released, pool accounting
        exact), or stop an active decode and free its slot.  A request
        found nowhere (mid-admission emit — e.g. an ``on_token`` callback
        cancelling its own request — or never admitted) is marked done;
        the admission paths check ``req.done`` after every emit and
        release the slot themselves.  False if already finished.  Safe
        from any thread: the whole teardown runs under the engine lock,
        atomically with respect to the loop's tick."""
        with self._lock:
            if req.done:
                return False
            if self.scheduler.remove(req):
                self._finish_cancel(req)
                return True
            for cp in self._chunked:
                if cp.req is req:
                    self._chunked.remove(cp)
                    self._free_slot(cp.slot)
                    self._finish_cancel(req)
                    return True
            if self.active.get(req.rid) is req:
                del self.active[req.rid]
                for s, r in enumerate(self.slots):
                    if r is req:
                        self._free_slot(s)
                        break
                self._finish_cancel(req)
                return True
            self._finish_cancel(req)
            return True

    def preempt(self, req: Request) -> bool:
        """Kick an ACTIVE request off its slot and requeue it: the slot
        and its reservation are released through the same exact-accounting
        teardown as :meth:`cancel`, the tokens emitted so far are folded
        into the prompt, and the request goes back on the scheduler — its
        re-admission re-prefills the extended prompt, so the continued
        greedy stream is token-identical to never having been preempted
        (pinned; sampled streams restart their per-token step counter at
        the new prefill boundary).  False if the request is not actively
        decoding (queued/staged requests hold no decode slot worth
        stealing) or the extended prompt would not fit ``max_seq``."""
        with self._lock:
            if req.done or self.active.get(req.rid) is not req:
                return False
            if len(req.prompt) + len(req.out) > self.max_seq - 1:
                return False       # nothing left to decode after requeue
            del self.active[req.rid]
            for s, r in enumerate(self.slots):
                if r is req:
                    self._free_slot(s)
                    break
            self.scheduler.clear_stall(req.rid)
            req.prompt = list(req.prompt) + list(req.out)
            self.scheduler.push(req)
            self.metrics.preemptions += 1
            self.tracer.event("preempt", rid=req.rid)
            self.tracer.event("queue", rid=req.rid)
            self._update_gauges()
        self._loop_wake.set()
        return True

    def _finish_cancel(self, req: Request):
        req.cancelled = True
        self.scheduler.clear_stall(req.rid)
        self.tracer.event("cancel", rid=req.rid)
        self._retire(req)
        self.metrics.cancelled += 1
        self._update_gauges()

    def _bucket_len(self, n: int) -> int:
        return min(ceil_div(n, self.prefill_bucket) * self.prefill_bucket,
                   self.max_seq)

    def _admit(self, reqs: list[Request], slots: list[int]):
        """Prefill ``reqs`` into ``slots`` — one jit call per length bucket,
        one cache scatter per bucket (no per-row update round-trips).
        Callers must have ``_validate``d (and ``_reserve``d) each request
        first."""
        assert len(reqs) == len(slots)
        for r, s in zip(reqs, slots):
            self.tracer.event("admit", rid=r.rid, slot=s, staged=False)
        prev_admitting = self._admitting
        self._admitting = True
        try:
            self._admit_buckets(reqs, slots)
        finally:
            self._admitting = prev_admitting

    def _admit_buckets(self, reqs: list[Request], slots: list[int]):
        buckets: dict[int, list[int]] = {}
        for i, r in enumerate(reqs):
            buckets.setdefault(self._bucket_len(len(r.prompt)), []).append(i)
        for blen, idxs in buckets.items():
            k = len(idxs)
            toks = np.zeros((k, blen), np.int32)
            last = np.zeros(k, np.int32)
            for j, i in enumerate(idxs):
                p = reqs[i].prompt
                toks[j, :len(p)] = p
                last[j] = len(p) - 1
            slot_ids = jnp.asarray([slots[i] for i in idxs])
            tables = self.backend.admission_tables([slots[i] for i in idxs])
            rids = jnp.asarray([reqs[i].rid for i in idxs], jnp.int32)
            t0 = self.clock()
            nxt, self.caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(last), slot_ids, tables, rids, self.key)
            nxt = np.asarray(nxt)          # sync for honest wall-clock
            dt = self.clock() - t0
            self.metrics.prefill_s += dt
            self.metrics.prefill_calls += 1
            self._h_phase.observe(dt, phase="prefill")
            self.tracer.event("prefill", ts=t0, dur=dt, batch=k)
            for j, i in enumerate(idxs):
                req, slot = reqs[i], slots[i]
                self._emit(req, int(nxt[j]))
                self.metrics.prefill_tokens += len(req.prompt)
                self._prefix_insert_from_slot(req, slot)
                if req.done or len(req.out) >= req.max_new:
                    # cap already met by the prefill-sampled token
                    # (max_new=1: done at admission, never decode-ticked)
                    # — or an on_token callback cancelled the request
                    # mid-emit, before it ever joined a slot
                    self._retire(req)
                    self.backend.free_slot(slot)
                    continue
                self.positions[slot] = len(req.prompt)
                self.slots[slot] = req
                self.active[req.rid] = req

    # --- staged (chunked / warm-prefix) prefill -------------------------
    def _start_staged(self, req: Request, slot: int, hit=None):
        """Reserve ``slot`` for a staged admission.  The prompt is fed to a
        staged 1-row cache — one chunk per tick under ``prefill_chunk``,
        synchronously otherwise — and the request only joins ``active``
        (decode) once the last piece lands.  A prefix ``hit`` seeds the
        staging row (shared blocks gathered + state snapshot) and skips the
        first ``hit.length`` prompt tokens; the final scatter of a warm
        paged admission redirects the shared-block range to the garbage
        block so a shared block is never written in place (copy-on-write)."""
        self.slots[slot] = req
        self.positions[slot] = 0
        consumed, scatter_table = 0, None
        if hit is not None:
            staging = self._seed_staging(hit)
            consumed = hit.length
            scatter_table = self.backend.cow_table(slot, len(hit.blocks))
            self.metrics.prefix_hits += 1
            self.metrics.prefix_tokens_reused += consumed
        else:
            staging = self.backend.fresh(1)
        self.tracer.event("admit", rid=req.rid, slot=slot, staged=True,
                          reused=consumed)
        cap = None
        if self.prefix_cache is not None and self.backend.needs_state:
            c = self._capture_boundary(len(req.prompt))
            if consumed < c < len(req.prompt):
                cap = c
        cp = _ChunkedPrefill(req, slot, staging, consumed, capture_at=cap,
                             scatter_table=scatter_table)
        self._chunked.append(cp)
        if self.prefill_chunk is None:
            # no chunked scheduling: drive the staged admission to
            # completion now, preserving admit-at-submit semantics (cp is
            # the only queue entry — earlier ones all drained the same way)
            while self._chunked and self._chunked[0] is cp:
                self._advance_chunked()

    def _advance_chunked(self):
        """Run AT MOST one prefill piece (FIFO head) — this bounds the
        prefill work any decode tick waits on to one chunk.  Pieces are cut
        at the state-capture grid boundary so the prefix cache can snapshot
        the staged recurrent state mid-prompt."""
        if not self._chunked:
            return
        cp = self._chunked[0]
        req = cp.req
        remaining = len(req.prompt) - cp.consumed
        c = self.prefill_chunk if self.prefill_chunk is not None \
            else remaining
        if cp.capture_at is not None and cp.consumed < cp.capture_at:
            c = min(c, cp.capture_at - cp.consumed)
        t0 = self.clock()
        if remaining > c:
            toks = np.asarray(req.prompt[cp.consumed:cp.consumed + c],
                              np.int32)[None]
            cp.staging = self._chunk_step(self.params, jnp.asarray(toks),
                                          cp.staging, jnp.int32(cp.consumed))
            jax.block_until_ready(cp.staging)
            cp.consumed += c
            dt = self.clock() - t0
            self.metrics.prefill_s += dt
            self.metrics.prefill_tokens += c
            self.metrics.prefill_calls += 1
            self._h_phase.observe(dt, phase="prefill")
            self.tracer.event("prefill", ts=t0, dur=dt, batch=1)
            if self.prefill_chunk is not None:
                self.metrics.prefill_chunks += 1
                self.tracer.event("prefill_chunk", rid=req.rid, ts=t0,
                                  consumed=cp.consumed)
            if cp.capture_at == cp.consumed:
                cp.captured = self.backend.snapshot(cp.staging, 0)
            return
        # final piece: pad to the bucket grid (static shapes), sample the
        # request's first token, scatter the staged row into the slab/pool
        self._chunked.pop(0)
        pl = min(self._bucket_len(remaining),
                 self.backend.stage_len - cp.consumed)
        toks = np.zeros((1, pl), np.int32)
        toks[0, :remaining] = req.prompt[cp.consumed:]
        slot_ids = jnp.asarray([cp.slot])
        tables = self.backend.finish_tables(cp.slot, cp.scatter_table)
        nxt, self.caches, staged_out = self._chunk_finish(
            self.params, jnp.asarray(toks), cp.staging,
            jnp.int32(cp.consumed), jnp.asarray([remaining - 1]),
            self.caches, slot_ids, tables, jnp.asarray([req.rid], jnp.int32),
            self.key)
        nxt = np.asarray(nxt)
        dt = self.clock() - t0
        self.metrics.prefill_s += dt
        self.metrics.prefill_tokens += remaining
        self.metrics.prefill_calls += 1
        self._h_phase.observe(dt, phase="prefill")
        self.tracer.event("prefill", ts=t0, dur=dt, batch=1)
        if self.prefill_chunk is not None:
            self.metrics.prefill_chunks += 1
            self.tracer.event("prefill_chunk", rid=req.rid, ts=t0,
                              consumed=len(req.prompt))
        self._finish_prefix_insert(cp, staged_out)
        self._emit(req, int(nxt[0]))
        if req.done or len(req.out) >= req.max_new:
            # cap met, or an on_token callback cancelled mid-emit
            self._retire(req)
            self._free_slot(cp.slot)
            return
        self.positions[cp.slot] = len(req.prompt)
        self.active[req.rid] = req

    # --- scheduler-driven admission -------------------------------------
    def _admit_pending(self):
        """Admit queued requests into free slots, highest effective
        priority first (deadline tie-break, one-bucket aging — see
        :class:`Scheduler`).  Cold same-tick admissions batch into one
        bucketed prefill call; a failed reservation stalls admission
        (head-of-line) until capacity grows."""
        free = [s for s, r in enumerate(self.slots) if r is None]
        batch: list[Request] = []
        batch_slots: list[int] = []
        while self.scheduler.pending and free:
            entry = self.scheduler.select()
            req = entry.req
            need = self.backend.reservation_need(len(req.prompt),
                                                 req.max_new)
            if self.scheduler.stalled(req.rid, self.backend.free_capacity,
                                      need):
                break
            try:
                self._validate(req)
                self._check_rid_free(req)
                if any(b.rid == req.rid for b in batch):
                    raise ValueError(
                        f"rid {req.rid} queued twice in one admission "
                        "tick — rids must be unique among live requests")
            except ValueError:
                # direct scheduler pushes bypass serve()'s pre-validation:
                # evict the poison entry so the queue stays serviceable,
                # flush the requests already committed this tick (their
                # blocks are reserved — dropping them would leak the
                # reservation and hang their callers), then surface the
                # error once
                self.scheduler.drop(entry)
                self._retire(req)
                if batch:
                    self._admit(batch, batch_slots)
                raise
            hit = self._match_prefix(req)
            if not self._reserve(req, free[0], hit):
                self.scheduler.note_stall(req.rid,
                                          self.backend.free_capacity, need)
                break          # head-of-line: wait for capacity to free
            self.scheduler.clear_stall(req.rid)
            self.scheduler.commit(entry)
            slot = free.pop(0)
            lone = not batch and not self.scheduler.pending
            if self._route_staged(req, hit, lone):
                self._start_staged(req, slot, hit)
            else:
                batch.append(req)
                batch_slots.append(slot)
        if batch:
            self._admit(batch, batch_slots)

    # --- decode ---------------------------------------------------------
    def step(self):
        """One engine tick, under the engine lock — the public, thread-safe
        spelling of :meth:`_tick` (safe to call even while the background
        loop runs: ticks serialize on the lock)."""
        with self._lock:
            self._tick()

    def _tick(self):
        """One engine tick: admit queued work into free slots, run at most
        one chunk of pending prefill, then every active slot advances one
        token at its own position (free or still-admitting rows compute
        masked garbage that is ignored — a mid-admission slot's garbage
        writes are fully overwritten by its final staged-cache scatter).
        Re-entrant (the lock is an RLock) and caller-agnostic: the
        synchronous ``serve()``/``step()`` path and the background loop
        both drive exactly this body, which is what pins loop-mode output
        token-identical to sync output.  Callers MUST hold the engine
        lock."""
        ta = self.clock()
        self._admit_pending()
        self._advance_chunked()
        dta = self.clock() - ta
        self._h_phase.observe(dta, phase="admit")
        self.tracer.event("admit", ts=ta, dur=dta)
        if not self.active:
            self._update_gauges()
            return
        if self._spec is None or not self._spec_tick():
            self._decode_tick()
        self._update_gauges()

    def _decode_tick(self):
        """The plain one-token decode advance: every active slot steps one
        token at its own position.  Also the speculative mode's fallback
        for ticks where no slot produced a draft — spec mode degrades to
        exactly this path, never stalls."""
        toks = np.zeros((self.max_batch, 1), np.int32)
        rids = np.full(self.max_batch, -1, np.int32)
        steps = np.zeros(self.max_batch, np.int32)
        n_active = 0
        for s, req in enumerate(self.slots):
            if req is not None and req.rid in self.active:
                toks[s, 0] = req.out[-1]
                rids[s] = req.rid
                steps[s] = len(req.out)
                n_active += 1
        tables = self.backend.decode_tables([cp.slot for cp in
                                             self._chunked])
        t0 = self.clock()
        nxt, self.caches = self._decode(
            self.decode_params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.positions), tables, jnp.asarray(rids),
            jnp.asarray(steps), self.key)
        nxt = np.asarray(nxt)
        dt = self.clock() - t0
        self.metrics.decode_s += dt
        self.metrics.ticks += 1
        self.metrics.occupancy_sum += n_active
        self.metrics.decode_tokens += n_active
        self._h_phase.observe(dt, phase="decode")
        self.tracer.event("decode", ts=t0, dur=dt, batch=n_active)
        t2 = self.clock()
        for s, req in enumerate(self.slots):
            if req is None or req.rid not in self.active:
                continue
            self._emit(req, int(nxt[s]))
            if req.done:
                # an on_token callback cancelled from inside the emit:
                # cancel() already freed the slot and active entry
                continue
            self.positions[s] += 1
            if len(req.out) >= req.max_new or \
                    self.positions[s] >= self.max_seq - 1:
                self._retire(req)
                self.active.pop(req.rid, None)
                self._free_slot(s)
        dte = self.clock() - t2
        self._h_phase.observe(dte, phase="emit")
        self.tracer.event("emit", ts=t2, dur=dte)

    def _spec_tick(self) -> bool:
        """One speculative advance: draft -> batched verify -> accept
        prefix -> rollback (see :mod:`repro.serve.spec` for the contract).
        False when no slot produced a draft — the caller then runs the
        plain :meth:`_decode_tick`, so speculation can only add tokens per
        tick, never lose them.

        Per-row draft budget: ``k_eff`` caps the window so the emitted
        ``accepted + 1`` tokens can never overrun ``max_new`` or write
        past ``max_seq - 1`` (the same retire boundary the plain tick
        enforces), hence retire checks below stay identical to
        :meth:`_decode_tick`'s.

        Cache discipline: verify runs one ``decode_window`` call against
        the live tree.  Rejected-position writes are dead weight on
        attention substrates (``CacheBackend.rollback`` is bookkeeping
        only; later writes land over them), but a recurrent state has
        already INGESTED the rejected tokens — so on a partial accept the
        window is re-run from the saved pre-verify tree with the SSD scan
        masked at each row's accept boundary (full acceptance skips the
        second pass: the verify-pass state is exactly the committed
        state)."""
        spec_k = self.config.spec_k
        reqs: list[Request | None] = [None] * self.max_batch
        k_eff = np.zeros(self.max_batch, np.int64)
        for s, req in enumerate(self.slots):
            if req is not None and req.rid in self.active:
                reqs[s] = req
                limit = min(len(req.prompt) + req.max_new, self.max_seq)
                k_eff[s] = max(0, min(
                    spec_k,
                    req.max_new - len(req.out) - 1,
                    limit - 2 - int(self.positions[s])))
        t0 = self.clock()
        drafts = self._spec.propose(reqs, k_eff.tolist())
        total = sum(len(d) for d in drafts)
        dt0 = self.clock() - t0
        self._h_phase.observe(dt0, phase="draft")
        self.tracer.event("draft", ts=t0, dur=dt0, drafted=total)
        if total == 0:
            return False
        self.metrics.spec_drafted += total
        toks = np.zeros((self.max_batch, spec_k + 1), np.int32)
        n_valid = np.zeros(self.max_batch, np.int32)
        n_active = 0
        for s, req in enumerate(reqs):
            if req is None:
                continue
            d = drafts[s]
            toks[s, 0] = req.out[-1]
            toks[s, 1:1 + len(d)] = d
            n_valid[s] = 1 + len(d)
            n_active += 1
        tables = self.backend.decode_tables([cp.slot for cp in
                                             self._chunked])
        pre = self.caches
        t1 = self.clock()
        tgt, post = self._verify(
            self.decode_params, jnp.asarray(toks), pre,
            jnp.asarray(self.positions), tables, jnp.asarray(n_valid),
            jnp.asarray(n_valid - 1))
        tgt = np.asarray(tgt)
        dt1 = self.clock() - t1
        self.metrics.decode_s += dt1
        self.metrics.ticks += 1
        self.metrics.spec_ticks += 1
        self.metrics.occupancy_sum += n_active
        self._h_phase.observe(dt1, phase="verify")
        self.tracer.event("verify", ts=t1, dur=dt1, batch=n_active)
        accepts = np.zeros(self.max_batch, np.int32)
        partial = False
        for s, req in enumerate(reqs):
            if req is not None:
                accepts[s] = accept_length(drafts[s], tgt[s])
                partial = partial or accepts[s] < len(drafts[s])
        if self.backend.needs_state and partial:
            commit_last = np.where(n_valid > 0, accepts, -1)
            t2 = self.clock()
            self.caches = self._spec_commit(
                self.decode_params, jnp.asarray(toks), pre,
                jnp.asarray(self.positions), tables,
                jnp.asarray(n_valid),
                jnp.asarray(commit_last.astype(np.int32)))
            jax.block_until_ready(self.caches)
            dt2 = self.clock() - t2
            self.metrics.decode_s += dt2
            self._h_phase.observe(dt2, phase="verify")
            self.tracer.event("verify", ts=t2, dur=dt2, batch=n_active,
                              commit=True)
        else:
            self.caches = post
        t3 = self.clock()
        emitted_total = 0
        for s, req in enumerate(reqs):
            if req is None or req.done or self.slots[s] is not req or \
                    req.rid not in self.active:
                continue   # a callback on an earlier row tore this one down
            m = int(accepts[s])
            rejected = len(drafts[s]) - m
            req._spec_accepted += m
            req._spec_rejected += rejected
            self.metrics.spec_accepted += m
            self.metrics.spec_rejected += rejected
            self._h_spec_window.observe(float(m), proposer=self._spec.name)
            for i in range(m + 1):
                self._emit(req, int(tgt[s, i]))
                emitted_total += 1
                if req.done or self.slots[s] is not req:
                    # an on_token callback cancelled/preempted this row
                    # mid-window: the teardown already released the slot —
                    # stop emitting and leave its bookkeeping alone
                    break
            else:
                self.positions[s] += m + 1
                if rejected:
                    self.backend.rollback(s, rejected)
                if len(req.out) >= req.max_new or \
                        self.positions[s] >= self.max_seq - 1:
                    self._retire(req)
                    self.active.pop(req.rid, None)
                    self._free_slot(s)
        self.metrics.decode_tokens += emitted_total
        dt3 = self.clock() - t3
        self._h_phase.observe(dt3, phase="emit")
        self.tracer.event("emit", ts=t3, dur=dt3)
        return True

    def serve(self, requests: list[Request], max_ticks: int = 512) -> dict:
        """Queue ``requests`` on the scheduler and run to completion (or
        ``max_ticks``): every tick admits queued requests into free slots
        in priority order (paged mode backpressures the head of the queue
        when the block pool is short), then decodes.  Returned stats cover
        THIS call only (``Engine.metrics`` keeps lifetime totals);
        requests still queued at ``max_ticks`` stay queued for the next
        ``serve()``/``step()`` call.  Requests are validated BEFORE they
        are queued — an invalid one raises here and nothing is enqueued
        (the persistent scheduler must never hold a request admission
        would reject forever)."""
        with self._lock:
            for r in requests:
                self._validate(r)
            now = self.clock()
            for r in requests:
                if r.submit_ts is None:
                    r.submit_ts = now
                self._note_submit(r)
                self.scheduler.push(r)
                self.tracer.event("queue", rid=r.rid)
            self._update_gauges()
            start = self.metrics.snapshot()
        t0 = self.clock()
        ticks = 0
        while (self.scheduler.pending or self.active or self._chunked) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        stats = self.metrics.since(start).summary(self.max_batch)
        stats.update({"wall_s": self.clock() - t0, "ticks": ticks,
                      "done": all(r.done for r in requests)})
        return stats

    # --- background serve loop ------------------------------------------
    @property
    def running(self) -> bool:
        """True while the background serve loop thread is alive."""
        t = self._loop_thread
        return t is not None and t.is_alive()

    def start(self) -> "Engine":
        """Run the engine tick on a background daemon thread until
        :meth:`stop`.  While running, ``submit()`` is the only client
        surface needed: handles stream via :meth:`RequestHandle.tokens`
        without anyone ticking the engine, and backpressured submits queue
        on the scheduler instead of bouncing.  Idempotent (a second
        ``start()`` on a running engine is a no-op); returns ``self`` so
        ``eng = Engine(...).start()`` reads naturally."""
        with self._lock:
            if self.running:
                return self
            self._loop_stop.clear()
            self._loop_wake.clear()
            self._drain_on_stop = True
            self._loop_thread = threading.Thread(
                target=self._serve_loop, name="engine-serve-loop",
                daemon=True)
            self._loop_thread.start()
        return self

    def stop(self, drain: bool = True, timeout: float | None = None):
        """Stop the background loop.  ``drain=True`` (default) keeps
        ticking until every queued, staged, and active request has
        finished before the thread exits — no token already submitted is
        lost.  ``drain=False`` exits at the next tick boundary; unfinished
        requests stay queued/active and a later ``start()``, ``serve()``
        or ``step()`` resumes them exactly where they stopped (state is
        only mutated under the lock, never torn down).  ``timeout`` bounds
        the join; returns True if the thread exited in time."""
        t = self._loop_thread
        if t is None or not t.is_alive():
            self._loop_thread = None
            return True
        self._drain_on_stop = drain
        self._loop_stop.set()
        self._loop_wake.set()
        t.join(timeout)
        alive = t.is_alive()
        if not alive:
            self._loop_thread = None
        return not alive

    def _serve_loop(self):
        """Loop body: tick while there is work, sleep ``idle_backoff_s``
        while there is none (a ``submit``/``cancel``/``preempt``/``stop``
        wakes the sleep immediately).  Every tick runs under the engine
        lock; between ticks the lock is released so client threads can
        submit/cancel without waiting out a whole generation."""
        backoff = max(self.config.idle_backoff_s, 1e-4)
        while True:
            with self._lock:
                worked = not self.idle
                if worked:
                    self._tick()
                drained = self.idle
            if self._loop_stop.is_set() and (drained
                                             or not self._drain_on_stop):
                return
            if not worked:
                self._loop_wake.wait(backoff)
                self._loop_wake.clear()
