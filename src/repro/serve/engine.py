"""Batched serving engine: prefill + decode with a static request slab.

Continuous-batching-lite: a fixed slab of ``max_batch`` sequence slots; new
requests prefill into free slots, every decode tick advances all active
slots one token (static shapes — jit caches exactly two programs).  Serving
the paper's technique = run with ``--quant luna_*`` so every projection goes
through the LUNA integer path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_model


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_seq: int = 256):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.caches = self.model.init_cache(max_batch, max_seq)
        self.positions = np.zeros(max_batch, np.int32)
        self.active: dict[int, Request] = {}
        self.slots: list[Request | None] = [None] * max_batch
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("prompt_len",))
        self._decode = jax.jit(self.model.decode_step)

    # --- jit bodies -----------------------------------------------------
    def _prefill_impl(self, params, tokens, caches, prompt_len):
        return self.model.prefill(params, tokens, caches)

    # --- public API -----------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Prefill into a free slot; returns False if the slab is full."""
        try:
            slot = self.slots.index(None)
        except ValueError:
            return False
        # single-row prefill (row batching of prefill is a perf follow-up)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        row_cache = self.model.init_cache(1, self.max_seq)
        logits, row_cache = self._prefill(self.params, toks, row_cache,
                                          prompt_len=len(req.prompt))
        # write the row cache back into the slab at `slot`
        self.caches = jax.tree.map(
            lambda slab, row: _write_row(slab, row, slot),
            self.caches, row_cache)
        nxt = int(jnp.argmax(logits[0, -1]))
        req.out.append(nxt)
        self.positions[slot] = len(req.prompt)
        self.slots[slot] = req
        self.active[req.rid] = req
        return True

    def step(self):
        """One decode tick for every active slot."""
        if not self.active:
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        for s, req in enumerate(self.slots):
            if req is not None and not req.done:
                toks[s, 0] = req.out[-1]
        index = int(self.positions.max())  # static-shape tick position
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches, jnp.int32(index))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[s]))
            self.positions[s] += 1
            if len(req.out) >= req.max_new or \
                    self.positions[s] >= self.max_seq - 1:
                req.done = True
                self.slots[s] = None
                del self.active[req.rid]

    def serve(self, requests: list[Request], max_ticks: int = 512):
        pending = list(requests)
        t0 = time.time()
        ticks = 0
        while (pending or self.active) and ticks < max_ticks:
            while pending and self.submit(pending[0]):
                pending.pop(0)
            self.step()
            ticks += 1
        return {"wall_s": time.time() - t0, "ticks": ticks,
                "done": all(r.done for r in requests)}


def _write_row(slab: jax.Array, row: jax.Array, slot: int) -> jax.Array:
    """Write a batch-1 row cache into the slab at ``slot`` (batch axis is the
    first axis where row is 1 and the slab is wider)."""
    if slab.shape == row.shape:        # max_batch == 1: row IS the slab
        return row.astype(slab.dtype)
    for ax in range(slab.ndim):
        if row.shape[ax] == 1 and slab.shape[ax] > 1:
            idx = [0] * slab.ndim
            idx[ax] = slot
            return jax.lax.dynamic_update_slice(slab, row.astype(slab.dtype),
                                                tuple(idx))
    return slab
