"""Continuous-batching serving engine: batched prefill + mixed-depth decode.

A fixed slab of ``max_batch`` sequence slots.  New requests are bucketed by
padded prompt length and prefilled in ONE jit call per bucket (rows are
written into the slab caches with a single batched scatter); every decode
tick advances all active slots one token **at their own position** — a
``(max_batch,)`` int32 position array is threaded through
``model.decode_step`` so rows of different depths attend over exactly their
own prefix (static shapes: jit caches one decode program plus one prefill
program per bucket shape).

Serving the paper's technique = run with ``--quant luna_*`` so every
projection goes through the LUNA integer path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, fields, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_model
from repro.serve.sampling import SamplingConfig, sample


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class EngineMetrics:
    """Wall-clock + token accounting split by phase."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0      # prompt tokens pushed through prefill
    decode_tokens: int = 0       # tokens emitted by decode ticks
    prefill_calls: int = 0
    ticks: int = 0
    occupancy_sum: int = 0       # sum over ticks of active slots

    def since(self, start: "EngineMetrics") -> "EngineMetrics":
        """Per-call delta: these counters minus a ``start`` snapshot (the
        engine-lifetime metrics keep accumulating across serve() calls)."""
        return EngineMetrics(**{
            f.name: getattr(self, f.name) - getattr(start, f.name)
            for f in fields(self)})

    def summary(self, max_batch: int) -> dict:
        d = {
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_calls": self.prefill_calls,
            "ticks": self.ticks,
            "prefill_tok_s": self.prefill_tokens / max(self.prefill_s, 1e-9),
            "decode_tok_s": self.decode_tokens / max(self.decode_s, 1e-9),
            "occupancy": (self.occupancy_sum / (self.ticks * max_batch)
                          if self.ticks else 0.0),
        }
        return d


# families whose caches tolerate right-padded prefill rows (attention masks
# the pad columns away); recurrent-state families (ssm/hybrid) fold every
# input token into their state, so they are only batched at EXACT lengths
PADDED_PREFILL_FAMILIES = ("dense", "moe")


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_seq: int = 256, sampling: SamplingConfig | None = None,
                 seed: int = 0, prefill_bucket: int = 16):
        if cfg.family in ("encdec", "vlm"):
            raise ValueError(
                f"family {cfg.family!r} needs modality inputs the text-only "
                "engine does not carry")
        if prefill_bucket < 1:
            raise ValueError(f"prefill_bucket must be >= 1, "
                             f"got {prefill_bucket}")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling or SamplingConfig()
        self.prefill_bucket = prefill_bucket
        self._pad_ok = cfg.family in PADDED_PREFILL_FAMILIES
        self.caches = self.model.init_cache(max_batch, max_seq)
        self._batch_axes = self._find_batch_axes()
        self.positions = np.zeros(max_batch, np.int32)
        self.key = jax.random.PRNGKey(seed)
        self.active: dict[int, Request] = {}
        self.slots: list[Request | None] = [None] * max_batch
        self.metrics = EngineMetrics()
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # --- cache-slab layout ----------------------------------------------
    def _find_batch_axes(self):
        """Per-leaf batch axis of the cache tree, found structurally by
        diffing the shapes of two differently-sized cache trees (cache
        layouts are family-specific: KV slabs are (B, S, ...), scanned
        layers stack an (L,) axis in front)."""
        a = self.model.init_cache(2, 4)
        b = self.model.init_cache(3, 4)

        def one(la, lb):
            diff = [ax for ax, (da, db) in enumerate(zip(la.shape, lb.shape))
                    if da != db]
            if len(diff) != 1:
                raise ValueError(
                    f"ambiguous batch axis for cache leaf {la.shape}")
            return diff[0]

        return jax.tree.map(one, a, b)

    def _scatter_rows(self, slab_tree, rows_tree, slots: jax.Array):
        """Write ``k`` freshly-prefilled cache rows into the slab at
        ``slots`` — one batched scatter per leaf, inside jit."""
        def one(slab, rows, ax):
            idx = (slice(None),) * ax + (slots,)
            return slab.at[idx].set(rows.astype(slab.dtype))

        return jax.tree.map(one, slab_tree, rows_tree, self._batch_axes)

    # --- jit bodies -----------------------------------------------------
    def _prefill_impl(self, params, tokens, slab, last_pos, slots, key):
        """Prefill a (k, L) token bucket against fresh (k, max_seq) caches,
        scatter the rows into the slab, sample each row's first token."""
        k = tokens.shape[0]
        fresh = self.model.init_cache(k, self.max_seq)
        logits, rows = self.model.prefill(params, tokens, fresh,
                                          last_pos=last_pos)
        new_slab = self._scatter_rows(slab, rows, slots)
        toks = sample(logits[:, 0], key, self.sampling)
        return toks, new_slab

    def _decode_impl(self, params, tokens, caches, positions, key):
        logits, new_caches = self.model.decode_step(
            params, tokens, caches, positions)
        toks = sample(logits[:, 0], key, self.sampling)
        return toks, new_caches

    # --- public API -----------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Prefill one request into a free slot; False if the slab is full."""
        free = [s for s, r in enumerate(self.slots) if r is None]
        if not free:
            return False
        self._admit([req], free[:1])
        return True

    def _bucket_len(self, n: int) -> int:
        if not self._pad_ok:
            return n                       # exact-length grouping only
        bl = -(-n // self.prefill_bucket) * self.prefill_bucket
        return min(bl, self.max_seq)

    def _admit(self, reqs: list[Request], slots: list[int]):
        """Prefill ``reqs`` into ``slots`` — one jit call per length bucket,
        one cache scatter per bucket (no per-row update round-trips)."""
        assert len(reqs) == len(slots)
        buckets: dict[int, list[int]] = {}
        for i, r in enumerate(reqs):
            if not (0 < len(r.prompt) <= self.max_seq - 1):
                raise ValueError(
                    f"request {r.rid}: prompt length {len(r.prompt)} not in "
                    f"[1, max_seq-1={self.max_seq - 1}]")
            buckets.setdefault(self._bucket_len(len(r.prompt)), []).append(i)
        for blen, idxs in buckets.items():
            k = len(idxs)
            toks = np.zeros((k, blen), np.int32)
            last = np.zeros(k, np.int32)
            for j, i in enumerate(idxs):
                p = reqs[i].prompt
                toks[j, :len(p)] = p
                last[j] = len(p) - 1
            self.key, sub = jax.random.split(self.key)
            t0 = time.perf_counter()
            nxt, self.caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(last), jnp.asarray([slots[i] for i in idxs]),
                sub)
            nxt = np.asarray(nxt)          # sync for honest wall-clock
            self.metrics.prefill_s += time.perf_counter() - t0
            self.metrics.prefill_calls += 1
            for j, i in enumerate(idxs):
                req, slot = reqs[i], slots[i]
                req.out.append(int(nxt[j]))
                self.positions[slot] = len(req.prompt)
                self.slots[slot] = req
                self.active[req.rid] = req
                self.metrics.prefill_tokens += len(req.prompt)

    def step(self):
        """One decode tick: every active slot advances one token at its own
        position (free/done rows compute masked garbage that is ignored)."""
        if not self.active:
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        n_active = 0
        for s, req in enumerate(self.slots):
            if req is not None and not req.done:
                toks[s, 0] = req.out[-1]
                n_active += 1
        self.key, sub = jax.random.split(self.key)
        t0 = time.perf_counter()
        nxt, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.positions), sub)
        nxt = np.asarray(nxt)
        self.metrics.decode_s += time.perf_counter() - t0
        self.metrics.ticks += 1
        self.metrics.occupancy_sum += n_active
        self.metrics.decode_tokens += n_active
        for s, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            req.out.append(int(nxt[s]))
            self.positions[s] += 1
            if len(req.out) >= req.max_new or \
                    self.positions[s] >= self.max_seq - 1:
                req.done = True
                self.slots[s] = None
                del self.active[req.rid]

    def serve(self, requests: list[Request], max_ticks: int = 512) -> dict:
        """Run to completion (or ``max_ticks``): admit pending requests into
        free slots in batched buckets, then tick decode.  Returned stats
        cover THIS call only (``Engine.metrics`` keeps lifetime totals)."""
        pending = list(requests)
        start = replace(self.metrics)
        t0 = time.time()
        ticks = 0
        while (pending or self.active) and ticks < max_ticks:
            free = [s for s, r in enumerate(self.slots) if r is None]
            if pending and free:
                n = min(len(pending), len(free))
                batch, pending = pending[:n], pending[n:]
                self._admit(batch, free[:n])
            self.step()
            ticks += 1
        stats = self.metrics.since(start).summary(self.max_batch)
        stats.update({"wall_s": time.time() - t0, "ticks": ticks,
                      "done": all(r.done for r in requests)})
        return stats
