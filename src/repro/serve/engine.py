"""Continuous-batching serving engine: batched prefill + mixed-depth decode.

A fixed set of ``max_batch`` sequence slots.  New requests are bucketed by
padded prompt length and prefilled in ONE jit call per bucket (rows are
written into the slab caches with a single batched scatter); every decode
tick advances all active slots one token **at their own position** — a
``(max_batch,)`` int32 position array is threaded through
``model.decode_step`` so rows of different depths attend over exactly their
own prefix (static shapes: jit caches one decode program plus one prefill
program per bucket shape).

Two cache substrates, token-identical by construction (the dense slab stays
as the reference oracle):

* **dense** (default) — per-slot (max_batch, max_seq, ...) cache rows; a
  slot reserves a full ``max_seq`` row for its whole lifetime.
* **paged** (``paged=True``) — the KV leaves become pools of
  ``num_blocks`` fixed ``block_size``-token blocks with a per-slot block
  table: admission reserves only ``ceil(min(len(prompt) + max_new,
  max_seq) / block_size)`` blocks (so decode can never run out
  mid-request), freeing a slot just returns its blocks to the pool, and a
  short request no longer pays a long request's reservation.  When the pool
  is short, admission backpressures (FIFO head-of-line) until blocks free.

**Chunked prefill** (``prefill_chunk=N``): prompts longer than N tokens are
admitted in N-token pieces interleaved with decode ticks — each tick runs
at most ONE chunk of prefill work before the decode step, so a
``max_seq``-long admission never stalls active decodes for more than one
chunk's worth of compute.  All served families: attention chunks continue
the staged KV cache at the write offset; the recurrent families resume the
mamba2 SSD scan from the carried (conv, state) — the scan accepts an
initial state and a pad-validity mask, so chunked and length-bucketed
prefill are both token-identical to whole-prompt prefill.

**Split substrate** (hybrid family, ``paged=True``): the shared attention
block's KV leaves live in the paged block pool (one block table per slot,
reused by every layer group) while the O(1)-per-slot SSM state stays dense
— each cache leaf gets the substrate that actually pays off.  The engine
routes scatters per leaf: block-table writes for pool leaves, slot-row
writes for dense leaves.

Sampling draws from per-request PRNG streams (``fold_in(seed_key, rid)``
then per-token step) — a request's sampled tokens are independent of its
slot index, co-tenants, and scheduling, for every sampling mode.

Serving the paper's technique = run with ``--quant luna_*`` so every
projection goes through the LUNA integer path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, fields, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_model
from repro.serve.paged import GARBAGE_BLOCK, BlockAllocator, blocks_needed
from repro.serve.sampling import SamplingConfig, sample


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class _ChunkedPrefill:
    """A long admission in flight: its reserved slot + staged cache rows."""
    req: Request
    slot: int
    staging: object        # dense (1, stage_len) cache tree
    consumed: int = 0      # prompt tokens already prefilled


@dataclass
class EngineMetrics:
    """Wall-clock + token accounting split by phase."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0      # prompt tokens pushed through prefill
    decode_tokens: int = 0       # tokens emitted by decode ticks
    prefill_calls: int = 0       # jit prefill invocations (bucket or chunk)
    prefill_chunks: int = 0      # chunked-admission pieces among those
    ticks: int = 0
    occupancy_sum: int = 0       # sum over ticks of active slots

    def since(self, start: "EngineMetrics") -> "EngineMetrics":
        """Per-call delta: these counters minus a ``start`` snapshot (the
        engine-lifetime metrics keep accumulating across serve() calls)."""
        return EngineMetrics(**{
            f.name: getattr(self, f.name) - getattr(start, f.name)
            for f in fields(self)})

    def summary(self, max_batch: int) -> dict:
        d = {
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": self.prefill_chunks,
            "ticks": self.ticks,
            "prefill_tok_s": self.prefill_tokens / max(self.prefill_s, 1e-9),
            "decode_tok_s": self.decode_tokens / max(self.decode_s, 1e-9),
            "occupancy": (self.occupancy_sum / (self.ticks * max_batch)
                          if self.ticks else 0.0),
        }
        return d


# every served family tolerates right-padded prefill rows: attention masks
# pad columns causally, and the recurrent families (ssm/hybrid) mask them
# out of the carried state (masked SSD scan + per-row conv-state gather)
PADDED_PREFILL_FAMILIES = ("dense", "moe", "ssm", "hybrid")

# families with attention KV leaves the paged block pool can back; "ssm"
# is excluded on purpose — its whole cache is O(1) recurrent state per
# slot, there is nothing to page
PAGED_FAMILIES = ("dense", "moe", "hybrid")


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_seq: int = 256, sampling: SamplingConfig | None = None,
                 seed: int = 0, prefill_bucket: int = 16,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: int | None = None,
                 prefill_chunk: int | None = None):
        if cfg.family in ("encdec", "vlm"):
            raise ValueError(
                f"family {cfg.family!r} needs modality inputs the text-only "
                "engine does not carry")
        if prefill_bucket < 1:
            raise ValueError(f"prefill_bucket must be >= 1, "
                             f"got {prefill_bucket}")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling or SamplingConfig()
        self.prefill_bucket = prefill_bucket
        if cfg.family not in PADDED_PREFILL_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} is not servable by this engine "
                f"(supported: {PADDED_PREFILL_FAMILIES})")
        if paged and cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"paged=True is not supported for family {cfg.family!r}: "
                "its cache is O(1) recurrent state per slot with no KV "
                f"leaves to page (paged families: {PAGED_FAMILIES})")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {prefill_chunk}")
        self.paged = paged
        self.prefill_chunk = prefill_chunk
        if paged:
            self.block_size = block_size
            self.blocks_per_row = -(-max_seq // block_size)
            self.num_blocks = (num_blocks if num_blocks is not None
                               else max_batch * self.blocks_per_row + 1)
            self.allocator = BlockAllocator(self.num_blocks, block_size)
            self.block_tables = np.full(
                (max_batch, self.blocks_per_row), GARBAGE_BLOCK, np.int32)
            self._slot_blocks: list[list[int]] = [[] for _ in
                                                  range(max_batch)]
            self.caches = self.model.init_cache(
                max_batch, max_seq, block_size=block_size,
                num_blocks=self.num_blocks)
            # staged/fresh prefill rows cover whole blocks for the scatter
            self._stage_len = self.blocks_per_row * block_size
        else:
            self.caches = self.model.init_cache(max_batch, max_seq)
            self._stage_len = max_seq
        self._batch_axes = self._find_batch_axes()
        self._paged_leaves = self._find_paged_leaves()
        self.positions = np.zeros(max_batch, np.int32)
        self.key = jax.random.PRNGKey(seed)
        self.active: dict[int, Request] = {}
        self.slots: list[Request | None] = [None] * max_batch
        self._chunked: list[_ChunkedPrefill] = []
        self.metrics = EngineMetrics()
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)
        self._chunk_step = jax.jit(self._chunk_step_impl)
        self._chunk_finish = jax.jit(self._chunk_finish_impl)

    # --- cache-slab layout ----------------------------------------------
    def _find_batch_axes(self):
        """Per-leaf batch axis of the cache tree, found structurally by
        diffing the shapes of two differently-sized DENSE cache trees
        (cache layouts are family-specific: KV slabs are (B, S, ...),
        scanned layers stack an (L,) axis in front).  Paged pools sit at
        the same tree positions, with (num_blocks, block_size) replacing
        (B, S) — the same axis indexes their block axis."""
        a = self.model.init_cache(2, 4)
        b = self.model.init_cache(3, 4)

        def one(la, lb):
            diff = [ax for ax, (da, db) in enumerate(zip(la.shape, lb.shape))
                    if da != db]
            if len(diff) != 1:
                raise ValueError(
                    f"ambiguous batch axis for cache leaf {la.shape}")
            return diff[0]

        return jax.tree.map(one, a, b)

    def _find_paged_leaves(self):
        """Boolean tree marking which cache leaves are paged block pools —
        found structurally by diffing a dense probe tree against a paged
        probe tree at sizes whose leading dims cannot coincide.  Hybrid's
        SPLIT SUBSTRATE falls out of this: its attention KV leaves differ
        (pool-shaped) while its dense SSM state leaves match."""
        if not self.paged:
            return jax.tree.map(lambda a: False, self.caches)
        dense = self.model.init_cache(2, 4)
        pooled = self.model.init_cache(2, 4, block_size=2, num_blocks=7)
        return jax.tree.map(lambda a, b: a.shape != b.shape, dense, pooled)

    def _scatter(self, slab_tree, rows_tree, slots, tables):
        """Write ``k`` freshly-prefilled cache rows into the slab — one
        batched scatter per leaf, inside jit.  Dense leaves land whole rows
        at ``slots``; paged-pool leaves are reshaped into
        (k, nblk, block_size, ...) blocks and scattered to the physical ids
        in ``tables`` (k, nblk).  Unreserved table entries all point at the
        garbage block — their writes collide there harmlessly (never read
        back)."""
        def one(slab, rows, ax, is_pool):
            if is_pool:
                bs = self.block_size
                shape = (rows.shape[:ax + 1] + (tables.shape[1], bs)
                         + rows.shape[ax + 2:])
                blocks = rows.reshape(shape).astype(slab.dtype)
                idx = (slice(None),) * ax + (tables,)
                return slab.at[idx].set(blocks)
            idx = (slice(None),) * ax + (slots,)
            return slab.at[idx].set(rows.astype(slab.dtype))

        return jax.tree.map(one, slab_tree, rows_tree, self._batch_axes,
                            self._paged_leaves)

    # --- jit bodies -----------------------------------------------------
    def _prefill_impl(self, params, tokens, slab, last_pos, slots, tables,
                      rids, key):
        """Prefill a (k, L) token bucket against fresh caches, scatter the
        rows into the slab (dense leaves: at slot ids; pool leaves: at
        block tables), sample each row's first token from its own stream."""
        k = tokens.shape[0]
        fresh = self.model.init_cache(k, self._stage_len)
        logits, rows = self.model.prefill(params, tokens, fresh,
                                          last_pos=last_pos)
        new_slab = self._scatter(slab, rows, slots, tables)
        toks = sample(logits[:, 0], key, self.sampling, rids=rids,
                      steps=jnp.zeros_like(rids))
        return toks, new_slab

    def _decode_impl(self, params, tokens, caches, positions, tables, rids,
                     steps, key):
        logits, new_caches = self.model.decode_step(
            params, tokens, caches, positions, block_tables=tables)
        toks = sample(logits[:, 0], key, self.sampling, rids=rids,
                      steps=steps)
        return toks, new_caches

    def _chunk_step_impl(self, params, tokens, staging, offset):
        """One mid-prompt chunk: continue the staged (1, stage_len) cache
        at ``offset`` (the trailing-logits matmul is 1 row — negligible)."""
        _, staging = self.model.prefill(params, tokens, staging,
                                        cache_index=offset)
        return staging

    def _chunk_finish_impl(self, params, tokens, staging, offset, last_pos,
                           slab, slots, tables, rid, key):
        """Final chunk: finish the staged row, sample its first token, and
        scatter the whole staged cache into the slab/pool in one go."""
        logits, staging = self.model.prefill(params, tokens, staging,
                                             last_pos=last_pos,
                                             cache_index=offset)
        new_slab = self._scatter(slab, staging, slots, tables)
        tok = sample(logits[:, 0], key, self.sampling, rids=rid,
                     steps=jnp.zeros_like(rid))
        return tok, new_slab

    # --- admission ------------------------------------------------------
    def _validate(self, req: Request):
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new must be >= 1 (prefill always "
                f"samples one token), got {req.max_new}")
        if not (0 < len(req.prompt) <= self.max_seq - 1):
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} not in "
                f"[1, max_seq-1={self.max_seq - 1}]")
        if self.paged and self._blocks_needed(req) > self.num_blocks - 1:
            raise ValueError(
                f"request {req.rid} needs {self._blocks_needed(req)} blocks "
                f"but the pool holds {self.num_blocks - 1}")

    def _blocks_needed(self, req: Request) -> int:
        return blocks_needed(len(req.prompt), req.max_new, self.max_seq,
                             self.block_size)

    def _reserve(self, req: Request, slot: int) -> bool:
        """Paged: claim the request's lifetime block budget up front, so a
        decode tick can never run out of blocks mid-request.  False =
        backpressure (pool short); dense mode always succeeds."""
        if not self.paged:
            return True
        blocks = self.allocator.alloc(self._blocks_needed(req))
        if blocks is None:
            return False
        self._slot_blocks[slot] = blocks
        self.block_tables[slot, :] = GARBAGE_BLOCK
        self.block_tables[slot, :len(blocks)] = blocks
        return True

    def _release_slot_resources(self, slot: int):
        if self.paged and self._slot_blocks[slot]:
            self.allocator.release(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self.block_tables[slot, :] = GARBAGE_BLOCK

    def _free_slot(self, slot: int):
        self.slots[slot] = None
        self.positions[slot] = 0
        self._release_slot_resources(slot)

    def _chunkable(self, prompt_len: int) -> bool:
        return (self.prefill_chunk is not None
                and prompt_len > self.prefill_chunk)

    # --- public API -----------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit one request; False if no slot is free (or, paged mode, the
        block pool is short).  Long prompts under ``prefill_chunk`` start a
        chunked admission — ``step()`` advances it one chunk per tick."""
        self._validate(req)
        free = [s for s, r in enumerate(self.slots) if r is None]
        if not free or not self._reserve(req, free[0]):
            return False
        if self._chunkable(len(req.prompt)):
            self._start_chunked(req, free[0])
        else:
            self._admit([req], free[:1])
        return True

    def _bucket_len(self, n: int) -> int:
        bl = -(-n // self.prefill_bucket) * self.prefill_bucket
        return min(bl, self.max_seq)

    def _admit(self, reqs: list[Request], slots: list[int]):
        """Prefill ``reqs`` into ``slots`` — one jit call per length bucket,
        one cache scatter per bucket (no per-row update round-trips).
        Callers must have ``_validate``d (and, paged, ``_reserve``d)
        each request first."""
        assert len(reqs) == len(slots)
        buckets: dict[int, list[int]] = {}
        for i, r in enumerate(reqs):
            buckets.setdefault(self._bucket_len(len(r.prompt)), []).append(i)
        for blen, idxs in buckets.items():
            k = len(idxs)
            toks = np.zeros((k, blen), np.int32)
            last = np.zeros(k, np.int32)
            for j, i in enumerate(idxs):
                p = reqs[i].prompt
                toks[j, :len(p)] = p
                last[j] = len(p) - 1
            slot_ids = jnp.asarray([slots[i] for i in idxs])
            tables = (jnp.asarray(self.block_tables[[slots[i] for i in idxs]])
                      if self.paged else None)
            rids = jnp.asarray([reqs[i].rid for i in idxs], jnp.int32)
            t0 = time.perf_counter()
            nxt, self.caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(last), slot_ids, tables, rids, self.key)
            nxt = np.asarray(nxt)          # sync for honest wall-clock
            self.metrics.prefill_s += time.perf_counter() - t0
            self.metrics.prefill_calls += 1
            for j, i in enumerate(idxs):
                req, slot = reqs[i], slots[i]
                req.out.append(int(nxt[j]))
                self.metrics.prefill_tokens += len(req.prompt)
                if len(req.out) >= req.max_new:
                    # cap already met by the prefill-sampled token
                    # (max_new=1): done at admission, never decode-ticked
                    req.done = True
                    self._release_slot_resources(slot)
                    continue
                self.positions[slot] = len(req.prompt)
                self.slots[slot] = req
                self.active[req.rid] = req

    # --- chunked prefill ------------------------------------------------
    def _start_chunked(self, req: Request, slot: int):
        """Reserve ``slot`` for a long admission; the prompt is fed to a
        staged 1-row cache one chunk per tick and only joins ``active``
        (decode) once the last chunk lands."""
        self.slots[slot] = req
        self.positions[slot] = 0
        self._chunked.append(_ChunkedPrefill(
            req, slot, self.model.init_cache(1, self._stage_len)))

    def _advance_chunked(self):
        """Run AT MOST one prefill chunk (FIFO head) — this bounds the
        prefill work any decode tick waits on to one chunk."""
        if not self._chunked:
            return
        cp = self._chunked[0]
        req, c = cp.req, self.prefill_chunk
        remaining = len(req.prompt) - cp.consumed
        t0 = time.perf_counter()
        if remaining > c:
            toks = np.asarray(req.prompt[cp.consumed:cp.consumed + c],
                              np.int32)[None]
            cp.staging = self._chunk_step(self.params, jnp.asarray(toks),
                                          cp.staging, jnp.int32(cp.consumed))
            jax.block_until_ready(cp.staging)
            cp.consumed += c
            self.metrics.prefill_s += time.perf_counter() - t0
            self.metrics.prefill_tokens += c
            self.metrics.prefill_calls += 1
            self.metrics.prefill_chunks += 1
            return
        # final piece: pad to the bucket grid (static shapes), sample the
        # request's first token, scatter the staged row into the slab/pool
        self._chunked.pop(0)
        pl = min(self._bucket_len(remaining), self._stage_len - cp.consumed)
        toks = np.zeros((1, pl), np.int32)
        toks[0, :remaining] = req.prompt[cp.consumed:]
        slot_ids = jnp.asarray([cp.slot])
        tables = (jnp.asarray(self.block_tables[cp.slot][None])
                  if self.paged else None)
        nxt, self.caches = self._chunk_finish(
            self.params, jnp.asarray(toks), cp.staging,
            jnp.int32(cp.consumed), jnp.asarray([remaining - 1]),
            self.caches, slot_ids, tables, jnp.asarray([req.rid], jnp.int32),
            self.key)
        nxt = np.asarray(nxt)
        self.metrics.prefill_s += time.perf_counter() - t0
        self.metrics.prefill_tokens += remaining
        self.metrics.prefill_calls += 1
        self.metrics.prefill_chunks += 1
        req.out.append(int(nxt[0]))
        if len(req.out) >= req.max_new:
            req.done = True
            self._free_slot(cp.slot)
            return
        self.positions[cp.slot] = len(req.prompt)
        self.active[req.rid] = req

    # --- decode ---------------------------------------------------------
    def step(self):
        """One engine tick: at most one chunk of pending prefill work, then
        every active slot advances one token at its own position (free or
        still-admitting rows compute masked garbage that is ignored — a
        mid-admission slot's garbage writes are fully overwritten by its
        final staged-cache scatter)."""
        self._advance_chunked()
        if not self.active:
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        rids = np.full(self.max_batch, -1, np.int32)
        steps = np.zeros(self.max_batch, np.int32)
        n_active = 0
        for s, req in enumerate(self.slots):
            if req is not None and req.rid in self.active:
                toks[s, 0] = req.out[-1]
                rids[s] = req.rid
                steps[s] = len(req.out)
                n_active += 1
        tables = jnp.asarray(self.block_tables) if self.paged else None
        t0 = time.perf_counter()
        nxt, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.positions), tables, jnp.asarray(rids),
            jnp.asarray(steps), self.key)
        nxt = np.asarray(nxt)
        self.metrics.decode_s += time.perf_counter() - t0
        self.metrics.ticks += 1
        self.metrics.occupancy_sum += n_active
        self.metrics.decode_tokens += n_active
        for s, req in enumerate(self.slots):
            if req is None or req.rid not in self.active:
                continue
            req.out.append(int(nxt[s]))
            self.positions[s] += 1
            if len(req.out) >= req.max_new or \
                    self.positions[s] >= self.max_seq - 1:
                req.done = True
                del self.active[req.rid]
                self._free_slot(s)

    def serve(self, requests: list[Request], max_ticks: int = 512) -> dict:
        """Run to completion (or ``max_ticks``): admit pending requests into
        free slots in batched buckets (FIFO; paged mode backpressures the
        head when the block pool is short), then tick.  Returned stats
        cover THIS call only (``Engine.metrics`` keeps lifetime totals)."""
        pending = list(requests)
        start = replace(self.metrics)
        t0 = time.time()
        ticks = 0
        while (pending or self.active or self._chunked) \
                and ticks < max_ticks:
            free = [s for s, r in enumerate(self.slots) if r is None]
            batch, batch_slots = [], []
            while pending and free:
                req = pending[0]
                self._validate(req)
                if not self._reserve(req, free[0]):
                    break          # head-of-line: wait for blocks to free
                pending.pop(0)
                slot = free.pop(0)
                if self._chunkable(len(req.prompt)):
                    self._start_chunked(req, slot)
                else:
                    batch.append(req)
                    batch_slots.append(slot)
            if batch:
                self._admit(batch, batch_slots)
            self.step()
            ticks += 1
        stats = self.metrics.since(start).summary(self.max_batch)
        stats.update({"wall_s": time.time() - t0, "ticks": ticks,
                      "done": all(r.done for r in requests)})
        return stats
