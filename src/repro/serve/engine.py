"""Continuous-batching serving engine: batched prefill + mixed-depth decode.

A fixed set of ``max_batch`` sequence slots.  New requests are bucketed by
padded prompt length and prefilled in ONE jit call per bucket (rows are
written into the slab caches with a single batched scatter); every decode
tick advances all active slots one token **at their own position** — a
``(max_batch,)`` int32 position array is threaded through
``model.decode_step`` so rows of different depths attend over exactly their
own prefix (static shapes: jit caches one decode program plus one prefill
program per bucket shape).

Two cache substrates, token-identical by construction (the dense slab stays
as the reference oracle):

* **dense** (default) — per-slot (max_batch, max_seq, ...) cache rows; a
  slot reserves a full ``max_seq`` row for its whole lifetime.
* **paged** (``paged=True``) — the KV leaves become pools of
  ``num_blocks`` fixed ``block_size``-token blocks with a per-slot block
  table: admission reserves only ``ceil(min(len(prompt) + max_new,
  max_seq) / block_size)`` blocks (so decode can never run out
  mid-request), freeing a slot just returns its blocks to the pool, and a
  short request no longer pays a long request's reservation.  When the pool
  is short, admission backpressures (FIFO head-of-line) until blocks free.

**Chunked prefill** (``prefill_chunk=N``): prompts longer than N tokens are
admitted in N-token pieces interleaved with decode ticks — each tick runs
at most ONE chunk of prefill work before the decode step, so a
``max_seq``-long admission never stalls active decodes for more than one
chunk's worth of compute.  All served families: attention chunks continue
the staged KV cache at the write offset; the recurrent families resume the
mamba2 SSD scan from the carried (conv, state) — the scan accepts an
initial state and a pad-validity mask, so chunked and length-bucketed
prefill are both token-identical to whole-prompt prefill.

**Split substrate** (hybrid family, ``paged=True``): the shared attention
block's KV leaves live in the paged block pool (one block table per slot,
reused by every layer group) while the O(1)-per-slot SSM state stays dense
— each cache leaf gets the substrate that actually pays off.  The engine
routes scatters per leaf: block-table writes for pool leaves, slot-row
writes for dense leaves.

**Prefix cache** (``prefix_cache=True``): a radix tree over prompt tokens
(``repro.serve.prefix_cache``) remembers what prefill already computed.
Admission matches the longest cached prefix and re-prefills only the
uncached tail — LUNA's capacity-for-computation bet applied to serving:

* attention families (``paged=True`` required): cached prefixes own
  refcounted pool blocks, shared COPY-ON-WRITE into the new request's
  block table (the tail lands in private blocks; the staged scatter's
  shared range is redirected to the garbage block, so a shared block is
  never written in place);
* recurrent families: cached prefixes store the fixed-size dense
  (conv_state, ssd_state) snapshot at the boundary, and the
  state-continuing SSD scan resumes from it; the hybrid combines both
  (paged attention blocks + state snapshot at block-aligned boundaries).

Warm admissions ride the same staged machinery as chunked prefill — whose
token-identity to whole-prompt prefill is already pinned — so warm output
is token-identical to cold for every family and both scheduler paths.

Sampling draws from per-request PRNG streams (``fold_in(seed_key, rid)``
then per-token step) — a request's sampled tokens are independent of its
slot index, co-tenants, and scheduling, for every sampling mode.

Serving the paper's technique = run with ``--quant luna_*`` so every
projection goes through the LUNA integer path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, fields, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_model
from repro.serve.paged import (GARBAGE_BLOCK, BlockAllocator, blocks_needed,
                               ceil_div)
from repro.serve.prefix_cache import PrefixCache
from repro.serve.sampling import SamplingConfig, sample


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = field(default_factory=list)
    done: bool = False


@dataclass(eq=False)
class _ChunkedPrefill:
    """A staged admission in flight: its reserved slot + staged cache rows
    (long chunked prompts, warm prefix-cache hits, and cold recurrent
    admissions that capture a mid-prompt state snapshot all ride this).
    ``eq=False``: identity semantics — field-wise ``==`` on staged jax
    pytrees is both meaningless and a crash."""
    req: Request
    slot: int
    staging: object        # dense (1, stage_len) cache tree
    consumed: int = 0      # prompt tokens already prefilled (or reused)
    capture_at: int | None = None   # grid boundary to snapshot state at
    captured: object | None = None  # the snapshot, once captured
    scatter_table: object | None = None  # COW redirect for the final scatter


@dataclass
class EngineMetrics:
    """Wall-clock + token accounting split by phase."""
    prefill_s: float = 0.0
    decode_s: float = 0.0
    prefill_tokens: int = 0      # prompt tokens pushed through prefill
    decode_tokens: int = 0       # tokens emitted by decode ticks
    prefill_calls: int = 0       # jit prefill invocations (bucket or chunk)
    prefill_chunks: int = 0      # chunked-admission pieces among those
    ticks: int = 0
    occupancy_sum: int = 0       # sum over ticks of active slots
    prefix_hits: int = 0         # admissions seeded from the prefix cache
    prefix_tokens_reused: int = 0   # prompt tokens NOT re-prefilled
    cache_evictions: int = 0     # prefix-cache nodes evicted (LRU)

    def since(self, start: "EngineMetrics") -> "EngineMetrics":
        """Per-call delta: these counters minus a ``start`` snapshot (the
        engine-lifetime metrics keep accumulating across serve() calls)."""
        return EngineMetrics(**{
            f.name: getattr(self, f.name) - getattr(start, f.name)
            for f in fields(self)})

    def summary(self, max_batch: int) -> dict:
        d = {
            "prefill_s": self.prefill_s,
            "decode_s": self.decode_s,
            "prefill_tokens": self.prefill_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_calls": self.prefill_calls,
            "prefill_chunks": self.prefill_chunks,
            "ticks": self.ticks,
            "prefill_tok_s": self.prefill_tokens / max(self.prefill_s, 1e-9),
            "decode_tok_s": self.decode_tokens / max(self.decode_s, 1e-9),
            "occupancy": (self.occupancy_sum / (self.ticks * max_batch)
                          if self.ticks else 0.0),
            "prefix_hits": self.prefix_hits,
            "prefix_tokens_reused": self.prefix_tokens_reused,
            "cache_evictions": self.cache_evictions,
        }
        return d


# every served family tolerates right-padded prefill rows: attention masks
# pad columns causally, and the recurrent families (ssm/hybrid) mask them
# out of the carried state (masked SSD scan + per-row conv-state gather)
PADDED_PREFILL_FAMILIES = ("dense", "moe", "ssm", "hybrid")

# families with attention KV leaves the paged block pool can back; "ssm"
# is excluded on purpose — its whole cache is O(1) recurrent state per
# slot, there is nothing to page
PAGED_FAMILIES = ("dense", "moe", "hybrid")


class Engine:
    def __init__(self, cfg, params, *, max_batch: int = 8,
                 max_seq: int = 256, sampling: SamplingConfig | None = None,
                 seed: int = 0, prefill_bucket: int = 16,
                 paged: bool = False, block_size: int = 16,
                 num_blocks: int | None = None,
                 prefill_chunk: int | None = None,
                 prefix_cache: bool = False,
                 prefix_cache_nodes: int = 256):
        if cfg.family in ("encdec", "vlm"):
            raise ValueError(
                f"family {cfg.family!r} needs modality inputs the text-only "
                "engine does not carry")
        if prefill_bucket < 1:
            raise ValueError(f"prefill_bucket must be >= 1, "
                             f"got {prefill_bucket}")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.sampling = sampling or SamplingConfig()
        self.prefill_bucket = prefill_bucket
        if cfg.family not in PADDED_PREFILL_FAMILIES:
            raise ValueError(
                f"family {cfg.family!r} is not servable by this engine "
                f"(supported: {PADDED_PREFILL_FAMILIES})")
        if paged and cfg.family not in PAGED_FAMILIES:
            raise ValueError(
                f"paged=True is not supported for family {cfg.family!r}: "
                "its cache is O(1) recurrent state per slot with no KV "
                f"leaves to page (paged families: {PAGED_FAMILIES})")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {prefill_chunk}")
        if prefix_cache and cfg.family in ("dense", "moe", "hybrid") \
                and not paged:
            raise ValueError(
                f"prefix_cache for family {cfg.family!r} shares its "
                "attention KV as copy-on-write paged blocks — construct "
                "with paged=True (the ssm family caches dense state "
                "snapshots and needs no paging)")
        self.paged = paged
        self.prefill_chunk = prefill_chunk
        if paged:
            self.block_size = block_size
            self.blocks_per_row = ceil_div(max_seq, block_size)
            self.num_blocks = (num_blocks if num_blocks is not None
                               else max_batch * self.blocks_per_row + 1)
            self.allocator = BlockAllocator(self.num_blocks, block_size)
            self.block_tables = np.full(
                (max_batch, self.blocks_per_row), GARBAGE_BLOCK, np.int32)
            self._slot_blocks: list[list[int]] = [[] for _ in
                                                  range(max_batch)]
            self.caches = self.model.init_cache(
                max_batch, max_seq, block_size=block_size,
                num_blocks=self.num_blocks)
            # staged/fresh prefill rows cover whole blocks for the scatter
            self._stage_len = self.blocks_per_row * block_size
        else:
            self.caches = self.model.init_cache(max_batch, max_seq)
            self._stage_len = max_seq
        self._batch_axes = self._find_batch_axes()
        self._paged_leaves = self._find_paged_leaves()
        self._needs_state = cfg.family in ("ssm", "hybrid")
        self.prefix_cache = None
        if prefix_cache:
            self.prefix_cache = PrefixCache(
                block_size=block_size if paged else None,
                allocator=self.allocator if paged else None,
                max_nodes=prefix_cache_nodes)
            # recurrent snapshots are captured on this boundary grid;
            # paged backends must land on whole blocks
            self._capture_grid = block_size if paged else prefill_bucket
        self._evictions_seen = 0
        self.positions = np.zeros(max_batch, np.int32)
        self.key = jax.random.PRNGKey(seed)
        self.active: dict[int, Request] = {}
        self.slots: list[Request | None] = [None] * max_batch
        self._chunked: list[_ChunkedPrefill] = []
        self.metrics = EngineMetrics()
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)
        self._chunk_step = jax.jit(self._chunk_step_impl)
        self._chunk_finish = jax.jit(self._chunk_finish_impl)
        self._seed_gather = jax.jit(self._seed_gather_impl)

    # --- cache-slab layout ----------------------------------------------
    def _find_batch_axes(self):
        """Per-leaf batch axis of the cache tree, found structurally by
        diffing the shapes of two differently-sized DENSE cache trees
        (cache layouts are family-specific: KV slabs are (B, S, ...),
        scanned layers stack an (L,) axis in front).  Paged pools sit at
        the same tree positions, with (num_blocks, block_size) replacing
        (B, S) — the same axis indexes their block axis."""
        a = self.model.init_cache(2, 4)
        b = self.model.init_cache(3, 4)

        def one(la, lb):
            diff = [ax for ax, (da, db) in enumerate(zip(la.shape, lb.shape))
                    if da != db]
            if len(diff) != 1:
                raise ValueError(
                    f"ambiguous batch axis for cache leaf {la.shape}")
            return diff[0]

        return jax.tree.map(one, a, b)

    def _find_paged_leaves(self):
        """Boolean tree marking which cache leaves are paged block pools —
        found structurally by diffing a dense probe tree against a paged
        probe tree at sizes whose leading dims cannot coincide.  Hybrid's
        SPLIT SUBSTRATE falls out of this: its attention KV leaves differ
        (pool-shaped) while its dense SSM state leaves match."""
        if not self.paged:
            return jax.tree.map(lambda a: False, self.caches)
        dense = self.model.init_cache(2, 4)
        pooled = self.model.init_cache(2, 4, block_size=2, num_blocks=7)
        return jax.tree.map(lambda a, b: a.shape != b.shape, dense, pooled)

    def _scatter(self, slab_tree, rows_tree, slots, tables):
        """Write ``k`` freshly-prefilled cache rows into the slab — one
        batched scatter per leaf, inside jit.  Dense leaves land whole rows
        at ``slots``; paged-pool leaves are reshaped into
        (k, nblk, block_size, ...) blocks and scattered to the physical ids
        in ``tables`` (k, nblk).  Unreserved table entries all point at the
        garbage block — their writes collide there harmlessly (never read
        back)."""
        def one(slab, rows, ax, is_pool):
            if is_pool:
                bs = self.block_size
                shape = (rows.shape[:ax + 1] + (tables.shape[1], bs)
                         + rows.shape[ax + 2:])
                blocks = rows.reshape(shape).astype(slab.dtype)
                idx = (slice(None),) * ax + (tables,)
                return slab.at[idx].set(blocks)
            idx = (slice(None),) * ax + (slots,)
            return slab.at[idx].set(rows.astype(slab.dtype))

        return jax.tree.map(one, slab_tree, rows_tree, self._batch_axes,
                            self._paged_leaves)

    # --- jit bodies -----------------------------------------------------
    def _prefill_impl(self, params, tokens, slab, last_pos, slots, tables,
                      rids, key):
        """Prefill a (k, L) token bucket against fresh caches, scatter the
        rows into the slab (dense leaves: at slot ids; pool leaves: at
        block tables), sample each row's first token from its own stream."""
        k = tokens.shape[0]
        fresh = self.model.init_cache(k, self._stage_len)
        logits, rows = self.model.prefill(params, tokens, fresh,
                                          last_pos=last_pos)
        new_slab = self._scatter(slab, rows, slots, tables)
        toks = sample(logits[:, 0], key, self.sampling, rids=rids,
                      steps=jnp.zeros_like(rids))
        return toks, new_slab

    def _decode_impl(self, params, tokens, caches, positions, tables, rids,
                     steps, key):
        logits, new_caches = self.model.decode_step(
            params, tokens, caches, positions, block_tables=tables)
        toks = sample(logits[:, 0], key, self.sampling, rids=rids,
                      steps=steps)
        return toks, new_caches

    def _chunk_step_impl(self, params, tokens, staging, offset):
        """One mid-prompt chunk: continue the staged (1, stage_len) cache
        at ``offset`` (the trailing-logits matmul is 1 row — negligible)."""
        _, staging = self.model.prefill(params, tokens, staging,
                                        cache_index=offset)
        return staging

    def _chunk_finish_impl(self, params, tokens, staging, offset, last_pos,
                           slab, slots, tables, rid, key):
        """Final chunk: finish the staged row, sample its first token, and
        scatter the whole staged cache into the slab/pool in one go.  The
        finished staging tree is also returned — the prefix cache snapshots
        its recurrent leaves (state at the full prompt boundary)."""
        logits, staging = self.model.prefill(params, tokens, staging,
                                             last_pos=last_pos,
                                             cache_index=offset)
        new_slab = self._scatter(slab, staging, slots, tables)
        tok = sample(logits[:, 0], key, self.sampling, rids=rid,
                     steps=jnp.zeros_like(rid))
        return tok, new_slab, staging

    # --- admission ------------------------------------------------------
    def _validate(self, req: Request):
        if req.max_new < 1:
            raise ValueError(
                f"request {req.rid}: max_new must be >= 1 (prefill always "
                f"samples one token), got {req.max_new}")
        if not (0 < len(req.prompt) <= self.max_seq - 1):
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} not in "
                f"[1, max_seq-1={self.max_seq - 1}]")
        if self.paged:
            need = blocks_needed(len(req.prompt), req.max_new, self.max_seq,
                                 self.block_size)
            if need > self.num_blocks - 1:
                raise ValueError(
                    f"request {req.rid} needs {need} blocks but the pool "
                    f"holds {self.num_blocks - 1}")

    def _reserve(self, req: Request, slot: int,
                 hit=None) -> bool:
        """Paged: claim the request's lifetime block budget up front, so a
        decode tick can never run out of blocks mid-request.  A prefix-hit
        admission refs the matched node's blocks (copy-on-write share) and
        allocates only the tail privately; when the pool runs short, LRU
        unreferenced cache nodes are evicted before backpressuring.  False =
        backpressure (pool short); dense mode always succeeds."""
        if not self.paged:
            return True
        shared = list(hit.blocks) if hit is not None else []
        need = blocks_needed(len(req.prompt), req.max_new, self.max_seq,
                             self.block_size) - len(shared)
        assert need >= 0, (need, len(shared))
        # take the request's ref BEFORE any eviction: the extra owner makes
        # the matched node's blocks non-evictable, so evict_for can neither
        # free them nor recycle them as this admission's private tail
        if shared:
            self.allocator.ref(shared)
        if need > self.allocator.free_blocks and self.prefix_cache:
            self.prefix_cache.evict_for(need)
            self._note_evictions()
        fresh = self.allocator.alloc(need)
        if fresh is None:
            if shared:
                self.allocator.release(shared)
            return False
        blocks = shared + fresh
        self._slot_blocks[slot] = blocks
        self.block_tables[slot, :] = GARBAGE_BLOCK
        self.block_tables[slot, :len(blocks)] = blocks
        return True

    def _note_evictions(self):
        """Fold the prefix cache's lifetime eviction count into the
        monotonic engine metrics."""
        if self.prefix_cache is not None:
            d = self.prefix_cache.evictions - self._evictions_seen
            self._evictions_seen = self.prefix_cache.evictions
            self.metrics.cache_evictions += d

    def _release_slot_resources(self, slot: int):
        if self.paged and self._slot_blocks[slot]:
            self.allocator.release(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self.block_tables[slot, :] = GARBAGE_BLOCK

    def _free_slot(self, slot: int):
        self.slots[slot] = None
        self.positions[slot] = 0
        self._release_slot_resources(slot)

    def _chunkable(self, prompt_len: int) -> bool:
        return (self.prefill_chunk is not None
                and prompt_len > self.prefill_chunk)

    # --- prefix cache ---------------------------------------------------
    def _match_prefix(self, req: Request):
        """Longest cached prefix usable for this admission (None = cold).
        At least one tail token must still run through prefill to produce
        the last-position logits, hence the ``len - 1`` cap."""
        if self.prefix_cache is None:
            return None
        return self.prefix_cache.match(req.prompt,
                                       max_len=len(req.prompt) - 1,
                                       need_state=self._needs_state)

    def _capture_boundary(self, prompt_len: int) -> int:
        """Grid boundary to snapshot recurrent state at (0 = none)."""
        return (prompt_len // self._capture_grid) * self._capture_grid

    def _route_staged(self, req: Request, hit, lone: bool = True) -> bool:
        """True when the admission must ride the staged path: chunked long
        prompts, every warm hit (the staging row is seeded from the cache),
        and LONE cold recurrent admissions that want a mid-prompt state
        snapshot (the prefill is split at the grid boundary to capture it).
        ``lone=False`` — other cold requests are being admitted this tick —
        keeps cold recurrent prompts on the batched bucket path: concurrent
        cold prefill throughput beats an extra capture boundary (the cache
        still populates from their full-prompt inserts and from warm /
        chunked admissions)."""
        if hit is not None or self._chunkable(len(req.prompt)):
            return True
        if not lone or self.prefix_cache is None or not self._needs_state:
            return False
        cap = self._capture_boundary(len(req.prompt))
        return 0 < cap < len(req.prompt)

    def _seed_gather_impl(self, caches, tbl):
        """Jit body: fresh 1-row staging tree with every pool leaf's shared
        blocks gathered into its dense staging leaf (logical order, exactly
        the values the cold prefill wrote).  Gathers run along each leaf's
        structural block axis (scan-stacked leaves carry a leading layer
        axis), mirroring ``_scatter``."""
        staging = self.model.init_cache(1, self._stage_len)

        def one(stg, pool, ax, is_pool):
            if not is_pool:
                return stg
            g = jnp.take(pool, tbl, axis=ax)      # (..., 1, nblk, bs, ...)
            return g.reshape(stg.shape)

        return jax.tree.map(one, staging, caches, self._batch_axes,
                            self._paged_leaves)

    def _seed_staging(self, hit):
        """Build the warm admission's staging row: gather the shared
        blocks' KV into the dense staging leaves (one jit call, compiled
        once) and swap in the recurrent state snapshot.  The tail prefill
        then continues at ``hit.length`` as if the first chunks had just
        run."""
        if self.paged and hit.blocks:
            table = np.full((1, self.blocks_per_row), GARBAGE_BLOCK,
                            np.int32)
            table[0, :len(hit.blocks)] = hit.blocks
            staging = self._seed_gather(self.caches, jnp.asarray(table))
        else:
            staging = self.model.init_cache(1, self._stage_len)
        if hit.state is not None:
            staging = self.model.seed_from_snapshot(staging, hit.state)
        return staging

    def _insert_boundary(self, prompt: list[int], slot: int, state):
        """One cached boundary — THE per-family storage policy: ssm needs
        only the state snapshot; attention families contribute the whole
        pool blocks of the prompt prefix (any grid multiple); the hybrid
        needs both halves at ONE boundary, so it stores only block-aligned
        prompts.  Blocks always come from the slot's reserved table."""
        fam = self.cfg.family
        if fam == "ssm":
            if state is not None:
                self.prefix_cache.insert(prompt, state=state)
            return
        nb = len(prompt) // self.block_size
        if nb == 0:
            return
        blocks = self._slot_blocks[slot][:nb]
        if fam == "hybrid":
            if state is None or len(prompt) % self.block_size:
                return
            self.prefix_cache.insert(prompt, blocks=blocks, state=state)
        else:
            self.prefix_cache.insert(prompt[:nb * self.block_size],
                                     blocks=blocks)

    def _prefix_insert_from_slot(self, req: Request, slot: int):
        """Cold batched admission: cache the freshly-prefilled prefix —
        state (if the family carries one) sliced from the slot's cache row
        at the full prompt boundary."""
        if self.prefix_cache is None:
            return
        state = (self.model.state_snapshot(self.caches, slot)
                 if self._needs_state else None)
        self._insert_boundary(req.prompt, slot, state)
        self._note_evictions()

    def _finish_prefix_insert(self, cp: _ChunkedPrefill, staged_out):
        """Staged admission done: insert the mid-prompt capture (if one was
        taken) and the full-prompt boundary into the radix tree."""
        if self.prefix_cache is None:
            return
        req, slot = cp.req, cp.slot
        if cp.captured is not None:
            self._insert_boundary(req.prompt[:cp.capture_at], slot,
                                  cp.captured)
        state = (self.model.state_snapshot(staged_out, 0)
                 if self._needs_state else None)
        self._insert_boundary(req.prompt, slot, state)
        self._note_evictions()

    # --- public API -----------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Admit one request; False if no slot is free (or, paged mode, the
        block pool is short).  Long prompts under ``prefill_chunk`` start a
        chunked admission — ``step()`` advances it one chunk per tick.
        With the prefix cache on, admission first matches the longest
        cached prompt prefix and prefills only the tail."""
        self._validate(req)
        free = [s for s, r in enumerate(self.slots) if r is None]
        if not free:
            return False
        hit = self._match_prefix(req)
        if not self._reserve(req, free[0], hit):
            return False
        if self._route_staged(req, hit):
            self._start_staged(req, free[0], hit)
        else:
            self._admit([req], free[:1])
        return True

    def _bucket_len(self, n: int) -> int:
        return min(ceil_div(n, self.prefill_bucket) * self.prefill_bucket,
                   self.max_seq)

    def _admit(self, reqs: list[Request], slots: list[int]):
        """Prefill ``reqs`` into ``slots`` — one jit call per length bucket,
        one cache scatter per bucket (no per-row update round-trips).
        Callers must have ``_validate``d (and, paged, ``_reserve``d)
        each request first."""
        assert len(reqs) == len(slots)
        buckets: dict[int, list[int]] = {}
        for i, r in enumerate(reqs):
            buckets.setdefault(self._bucket_len(len(r.prompt)), []).append(i)
        for blen, idxs in buckets.items():
            k = len(idxs)
            toks = np.zeros((k, blen), np.int32)
            last = np.zeros(k, np.int32)
            for j, i in enumerate(idxs):
                p = reqs[i].prompt
                toks[j, :len(p)] = p
                last[j] = len(p) - 1
            slot_ids = jnp.asarray([slots[i] for i in idxs])
            tables = (jnp.asarray(self.block_tables[[slots[i] for i in idxs]])
                      if self.paged else None)
            rids = jnp.asarray([reqs[i].rid for i in idxs], jnp.int32)
            t0 = time.perf_counter()
            nxt, self.caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(last), slot_ids, tables, rids, self.key)
            nxt = np.asarray(nxt)          # sync for honest wall-clock
            self.metrics.prefill_s += time.perf_counter() - t0
            self.metrics.prefill_calls += 1
            for j, i in enumerate(idxs):
                req, slot = reqs[i], slots[i]
                req.out.append(int(nxt[j]))
                self.metrics.prefill_tokens += len(req.prompt)
                self._prefix_insert_from_slot(req, slot)
                if len(req.out) >= req.max_new:
                    # cap already met by the prefill-sampled token
                    # (max_new=1): done at admission, never decode-ticked
                    req.done = True
                    self._release_slot_resources(slot)
                    continue
                self.positions[slot] = len(req.prompt)
                self.slots[slot] = req
                self.active[req.rid] = req

    # --- staged (chunked / warm-prefix) prefill -------------------------
    def _start_staged(self, req: Request, slot: int, hit=None):
        """Reserve ``slot`` for a staged admission.  The prompt is fed to a
        staged 1-row cache — one chunk per tick under ``prefill_chunk``,
        synchronously otherwise — and the request only joins ``active``
        (decode) once the last piece lands.  A prefix ``hit`` seeds the
        staging row (shared blocks gathered + state snapshot) and skips the
        first ``hit.length`` prompt tokens; the final scatter of a warm
        paged admission redirects the shared-block range to the garbage
        block so a shared block is never written in place (copy-on-write)."""
        self.slots[slot] = req
        self.positions[slot] = 0
        consumed, scatter_table = 0, None
        if hit is not None:
            staging = self._seed_staging(hit)
            consumed = hit.length
            if self.paged:
                scatter_table = self.block_tables[slot].copy()
                scatter_table[:len(hit.blocks)] = GARBAGE_BLOCK
            self.metrics.prefix_hits += 1
            self.metrics.prefix_tokens_reused += consumed
        else:
            staging = self.model.init_cache(1, self._stage_len)
        cap = None
        if self.prefix_cache is not None and self._needs_state:
            c = self._capture_boundary(len(req.prompt))
            if consumed < c < len(req.prompt):
                cap = c
        cp = _ChunkedPrefill(req, slot, staging, consumed, capture_at=cap,
                             scatter_table=scatter_table)
        self._chunked.append(cp)
        if self.prefill_chunk is None:
            # no chunked scheduling: drive the staged admission to
            # completion now, preserving admit-at-submit semantics (cp is
            # the only queue entry — earlier ones all drained the same way)
            while self._chunked and self._chunked[0] is cp:
                self._advance_chunked()

    def _advance_chunked(self):
        """Run AT MOST one prefill piece (FIFO head) — this bounds the
        prefill work any decode tick waits on to one chunk.  Pieces are cut
        at the state-capture grid boundary so the prefix cache can snapshot
        the staged recurrent state mid-prompt."""
        if not self._chunked:
            return
        cp = self._chunked[0]
        req = cp.req
        remaining = len(req.prompt) - cp.consumed
        c = self.prefill_chunk if self.prefill_chunk is not None \
            else remaining
        if cp.capture_at is not None and cp.consumed < cp.capture_at:
            c = min(c, cp.capture_at - cp.consumed)
        t0 = time.perf_counter()
        if remaining > c:
            toks = np.asarray(req.prompt[cp.consumed:cp.consumed + c],
                              np.int32)[None]
            cp.staging = self._chunk_step(self.params, jnp.asarray(toks),
                                          cp.staging, jnp.int32(cp.consumed))
            jax.block_until_ready(cp.staging)
            cp.consumed += c
            self.metrics.prefill_s += time.perf_counter() - t0
            self.metrics.prefill_tokens += c
            self.metrics.prefill_calls += 1
            if self.prefill_chunk is not None:
                self.metrics.prefill_chunks += 1
            if cp.capture_at == cp.consumed:
                cp.captured = self.model.state_snapshot(cp.staging, 0)
            return
        # final piece: pad to the bucket grid (static shapes), sample the
        # request's first token, scatter the staged row into the slab/pool
        self._chunked.pop(0)
        pl = min(self._bucket_len(remaining), self._stage_len - cp.consumed)
        toks = np.zeros((1, pl), np.int32)
        toks[0, :remaining] = req.prompt[cp.consumed:]
        slot_ids = jnp.asarray([cp.slot])
        if self.paged:
            table = (cp.scatter_table if cp.scatter_table is not None
                     else self.block_tables[cp.slot])
            tables = jnp.asarray(table[None])
        else:
            tables = None
        nxt, self.caches, staged_out = self._chunk_finish(
            self.params, jnp.asarray(toks), cp.staging,
            jnp.int32(cp.consumed), jnp.asarray([remaining - 1]),
            self.caches, slot_ids, tables, jnp.asarray([req.rid], jnp.int32),
            self.key)
        nxt = np.asarray(nxt)
        self.metrics.prefill_s += time.perf_counter() - t0
        self.metrics.prefill_tokens += remaining
        self.metrics.prefill_calls += 1
        if self.prefill_chunk is not None:
            self.metrics.prefill_chunks += 1
        self._finish_prefix_insert(cp, staged_out)
        req.out.append(int(nxt[0]))
        if len(req.out) >= req.max_new:
            req.done = True
            self._free_slot(cp.slot)
            return
        self.positions[cp.slot] = len(req.prompt)
        self.active[req.rid] = req

    # --- decode ---------------------------------------------------------
    def step(self):
        """One engine tick: at most one chunk of pending prefill work, then
        every active slot advances one token at its own position (free or
        still-admitting rows compute masked garbage that is ignored — a
        mid-admission slot's garbage writes are fully overwritten by its
        final staged-cache scatter)."""
        self._advance_chunked()
        if not self.active:
            return
        toks = np.zeros((self.max_batch, 1), np.int32)
        rids = np.full(self.max_batch, -1, np.int32)
        steps = np.zeros(self.max_batch, np.int32)
        n_active = 0
        for s, req in enumerate(self.slots):
            if req is not None and req.rid in self.active:
                toks[s, 0] = req.out[-1]
                rids[s] = req.rid
                steps[s] = len(req.out)
                n_active += 1
        tables = None
        if self.paged:
            tables = self.block_tables
            if self._chunked:
                # mid-admission slots decode masked garbage at position 0 —
                # park their rows on the garbage block so the write can
                # never land in a reserved block (a warm admission's table
                # starts with SHARED prefix blocks, which must never be
                # written in place)
                tables = tables.copy()
                for cp in self._chunked:
                    tables[cp.slot, :] = GARBAGE_BLOCK
            tables = jnp.asarray(tables)
        t0 = time.perf_counter()
        nxt, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.positions), tables, jnp.asarray(rids),
            jnp.asarray(steps), self.key)
        nxt = np.asarray(nxt)
        self.metrics.decode_s += time.perf_counter() - t0
        self.metrics.ticks += 1
        self.metrics.occupancy_sum += n_active
        self.metrics.decode_tokens += n_active
        for s, req in enumerate(self.slots):
            if req is None or req.rid not in self.active:
                continue
            req.out.append(int(nxt[s]))
            self.positions[s] += 1
            if len(req.out) >= req.max_new or \
                    self.positions[s] >= self.max_seq - 1:
                req.done = True
                del self.active[req.rid]
                self._free_slot(s)

    def serve(self, requests: list[Request], max_ticks: int = 512) -> dict:
        """Run to completion (or ``max_ticks``): admit pending requests into
        free slots in batched buckets (FIFO; paged mode backpressures the
        head when the block pool is short), then tick.  Returned stats
        cover THIS call only (``Engine.metrics`` keeps lifetime totals)."""
        pending = list(requests)
        start = replace(self.metrics)
        t0 = time.time()
        ticks = 0
        stall = None               # (rid, free_blocks) at the last failure
        while (pending or self.active or self._chunked) \
                and ticks < max_ticks:
            free = [s for s, r in enumerate(self.slots) if r is None]
            batch, batch_slots = [], []
            while pending and free:
                req = pending[0]
                # a backpressured head retries only once blocks have freed:
                # re-matching every tick would walk the radix tree, churn
                # ref/release on the shared blocks, and re-stamp the matched
                # path's LRU age for nothing
                if stall is not None and stall[0] == req.rid \
                        and self.allocator.free_blocks <= stall[1]:
                    break
                self._validate(req)
                hit = self._match_prefix(req)
                if not self._reserve(req, free[0], hit):
                    stall = (req.rid, self.allocator.free_blocks)
                    break          # head-of-line: wait for blocks to free
                stall = None
                pending.pop(0)
                slot = free.pop(0)
                lone = not batch and len(pending) == 0
                if self._route_staged(req, hit, lone):
                    self._start_staged(req, slot, hit)
                else:
                    batch.append(req)
                    batch_slots.append(slot)
            if batch:
                self._admit(batch, batch_slots)
            self.step()
            ticks += 1
        stats = self.metrics.since(start).summary(self.max_batch)
        stats.update({"wall_s": time.time() - t0, "ticks": ticks,
                      "done": all(r.done for r in requests)})
        return stats
