"""Cache substrates behind one protocol: the engine is substrate-blind.

Before this module the engine branched on ``PAGED_FAMILIES``, probed pool
leaves inline, and carried per-family seed/snapshot paths.  Now every
substrate decision lives behind :class:`CacheBackend`:

* :class:`DenseSlab` — per-slot (max_batch, max_seq, ...) rows; a slot
  reserves a full row for its lifetime (the reference oracle).
* :class:`PagedPool` — every pageable KV leaf becomes a pool of
  ``num_blocks`` fixed ``block_size``-token blocks with per-slot block
  tables; admission reserves only the request's lifetime block budget and
  backpressures when the pool is short (attention families).
* :class:`RecurrentState` — dense O(1)-per-slot recurrent state plus the
  snapshot/seed hooks the prefix cache needs (ssm).
* :class:`HybridComposite` — the split substrate: paged attention pools
  AND dense recurrent state, discovered structurally per leaf (hybrid).

A backend owns allocation (``reserve``/``free_slot``), the block tables
(admission/decode/copy-on-write scatter redirects), the jit-safe
scatter/gather routing along each leaf's structural batch axis, the
recurrent snapshot policy, and the prefix-cache storage policy
(``prefix_payload``).  The paged backends also expose the narrow block-op
surface (``ref``/``release``/``refcount``/``writable``/``free_blocks``)
that ``repro.serve.prefix_cache`` programs against — the cache talks to
the backend, never to ``BlockAllocator`` internals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import CacheSpec
from repro.serve.paged import (GARBAGE_BLOCK, BlockAllocator, blocks_needed,
                               ceil_div)

# every served family tolerates right-padded prefill rows: attention masks
# pad columns causally, and the recurrent families (ssm/hybrid) mask them
# out of the carried state (masked SSD scan + per-row conv-state gather)
SERVED_FAMILIES = ("dense", "moe", "ssm", "hybrid")

# families with attention KV leaves the paged block pool can back; "ssm"
# is excluded on purpose — its whole cache is O(1) recurrent state per
# slot, there is nothing to page
PAGED_FAMILIES = ("dense", "moe", "hybrid")

# families whose cache carries recurrent state the prefix cache snapshots
RECURRENT_FAMILIES = ("ssm", "hybrid")


class CacheBackend:
    """Base substrate: dense per-slot rows.  Subclasses override the
    reservation, table, snapshot, and prefix-policy hooks; the probe and
    scatter/gather machinery is shared (it is structural, not per-family).
    """

    paged = False
    needs_state = False

    def __init__(self, model, max_batch: int, max_seq: int,
                 spec: CacheSpec | None = None):
        self.model = model
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.spec = spec
        self.caches = model.init_cache(max_batch, max_seq, spec=spec)
        self.stage_len = max_seq
        self._batch_axes = self._find_batch_axes()
        self._pool_leaves = self._find_pool_leaves()
        self._lock = None

    # --- thread discipline ----------------------------------------------
    def bind_lock(self, lock) -> None:
        """The engine hands over its state lock: backend state (block
        pool accounting, slot tables, the cache slab reference) is only
        ever mutated while that lock is held — the tick and the public
        engine mutators all run under it, so the backend itself stays
        lock-free with a single-writer guarantee.  Mutating entry points
        assert the discipline instead of silently racing."""
        self._lock = lock

    def _assert_owned(self) -> None:
        lock = self._lock
        if lock is not None:
            owned = getattr(lock, "_is_owned", None)
            assert owned is None or owned(), \
                "backend state mutated without holding the engine lock"

    # --- cache-slab layout (structural probes) --------------------------
    def _find_batch_axes(self):
        """Per-leaf batch axis of the cache tree, found structurally by
        diffing the shapes of two differently-sized DENSE cache trees
        (cache layouts are family-specific: KV slabs are (B, S, ...),
        scanned layers stack an (L,) axis in front).  Paged pools sit at
        the same tree positions, with (num_blocks, block_size) replacing
        (B, S) — the same axis indexes their block axis."""
        a = self.model.init_cache(2, 4)
        b = self.model.init_cache(3, 4)

        def one(la, lb):
            diff = [ax for ax, (da, db) in enumerate(zip(la.shape, lb.shape))
                    if da != db]
            if len(diff) != 1:
                raise ValueError(
                    f"ambiguous batch axis for cache leaf {la.shape}")
            return diff[0]

        return jax.tree.map(one, a, b)

    def _find_pool_leaves(self):
        """Boolean tree marking which cache leaves are paged block pools —
        found structurally by diffing a dense probe tree against a paged
        probe tree at sizes whose leading dims cannot coincide.  Hybrid's
        SPLIT SUBSTRATE falls out of this: its attention KV leaves differ
        (pool-shaped) while its dense SSM state leaves match."""
        if self.spec is None or not self.spec.paged:
            return jax.tree.map(lambda a: False, self.caches)
        dense = self.model.init_cache(2, 4)
        pooled = self.model.init_cache(2, 4, spec=CacheSpec(2, 7))
        return jax.tree.map(lambda a, b: a.shape != b.shape, dense, pooled)

    # --- jit-safe bodies ------------------------------------------------
    def fresh(self, batch: int):
        """Fresh dense (batch, stage_len) staging tree (jit-safe)."""
        return self.model.init_cache(batch, self.stage_len)

    def scatter(self, slab_tree, rows_tree, slots, tables):
        """Write ``k`` freshly-prefilled cache rows into the slab — one
        batched scatter per leaf, inside jit.  Dense leaves land whole rows
        at ``slots``; pool leaves are reshaped into
        (k, nblk, block_size, ...) blocks and scattered to the physical ids
        in ``tables`` (k, nblk).  Unreserved table entries all point at the
        garbage block — their writes collide there harmlessly (never read
        back)."""
        def one(slab, rows, ax, is_pool):
            if is_pool:
                bs = self.spec.block_size
                shape = (rows.shape[:ax + 1] + (tables.shape[1], bs)
                         + rows.shape[ax + 2:])
                blocks = rows.reshape(shape).astype(slab.dtype)
                idx = (slice(None),) * ax + (tables,)
                return slab.at[idx].set(blocks)
            idx = (slice(None),) * ax + (slots,)
            return slab.at[idx].set(rows.astype(slab.dtype))

        return jax.tree.map(one, slab_tree, rows_tree, self._batch_axes,
                            self._pool_leaves)

    def gather_staging(self, caches, tbl):
        """Jit body: fresh 1-row staging tree with every pool leaf's shared
        blocks gathered into its dense staging leaf (logical order, exactly
        the values the cold prefill wrote).  Gathers run along each leaf's
        structural block axis (scan-stacked leaves carry a leading layer
        axis), mirroring :meth:`scatter`.  Dense leaves stay fresh."""
        staging = self.fresh(1)

        def one(stg, pool, ax, is_pool):
            if not is_pool:
                return stg
            g = jnp.take(pool, tbl, axis=ax)      # (..., 1, nblk, bs, ...)
            return g.reshape(stg.shape)

        return jax.tree.map(one, staging, caches, self._batch_axes,
                            self._pool_leaves)

    # --- decode weights (backend-owned quantized state) -----------------
    def prepare_decode_params(self, params, quant: str | None):
        """Freeze the decode-step weight tree once at construction.

        ``quant=None`` keeps the caller's tree untouched (decode params ARE
        the prefill params — the token-identity guarantee).  ``"lut4"`` /
        ``"int4"`` replace every decode-projection leaf with a 4-bit
        :class:`~repro.core.quant.QuantizedWeight` on the exact affine
        grid (D&C sub-table LUT vs direct-dequant evaluation);
        ``"nf4"`` / ``"nf4p"`` freeze the same leaves against the
        non-affine NF4 codebook, carrying the least-squares D&C split plus
        its per-code residual (full, or pruned below the magnitude
        threshold).  The quantized tree is backend-owned state, like the
        cache slab: prefill always runs the full-precision tree, only the
        decode hot path reads this one.
        """
        if quant is None:
            self.decode_params = params
        else:
            from repro.core.quant import quantize_decode_params
            self.decode_params = quantize_decode_params(params, quant)
        return self.decode_params

    # --- host-side reservation ------------------------------------------
    def validate_request(self, rid: int, prompt_len: int,
                         max_new: int) -> None:
        """Raise for requests this substrate can NEVER serve."""

    def reservation_need(self, prompt_len: int, max_new: int) -> int:
        """Capacity units :meth:`reserve` would claim (the scheduler's
        stall gate compares failed demands).  Dense substrates need only
        the slot the caller already holds."""
        return 0

    def reserve(self, slot: int, prompt_len: int, max_new: int,
                shared: list[int] | None = None, on_short=None) -> bool:
        """Claim the request's lifetime capacity; False = backpressure.
        The dense slab's capacity IS the slot, already held by the
        caller."""
        return True

    def free_slot(self, slot: int) -> None:
        """Return a slot's substrate resources (no-op for dense rows)."""

    def rollback(self, slot: int, n: int) -> None:
        """Discard the last ``n`` REJECTED speculative tokens of ``slot``.

        Every substrate supports this; what it costs differs.  Dense
        attention rows are position-indexed: the engine rewinds the
        slot's decode pointer and the rejected KV beyond it becomes dead
        weight the next writes overwrite — rollback is pure bookkeeping
        (kv_len masking already hides the junk from attention).  The
        recurrent substrates override the DOC, not the mechanics: their
        state cannot rewind positionally, so the engine re-commits it
        from the pre-verify cache tree with the SSD scan masked at the
        accept boundary (``Engine._spec_tick``); the backend-level call
        still runs to assert the locking discipline and validate the
        slot's accounting."""
        self._assert_owned()
        assert n >= 0, n

    def slot_blocks(self, slot: int) -> list[int]:
        return []

    @property
    def free_capacity(self) -> int:
        """Reservation headroom the scheduler's stall bookkeeping watches
        (paged: free blocks).  Dense reservation never fails, so any
        constant works."""
        return self.max_batch

    @property
    def total_capacity(self) -> int:
        """Capacity ceiling in the same unit as :attr:`free_capacity`
        (dense: slots; paged: reservable blocks) — lets the pool-occupancy
        gauge report a meaningful fraction."""
        return self.max_batch

    # --- block tables (all None for dense substrates) -------------------
    def admission_tables(self, slots: list[int]):
        return None

    def decode_tables(self, staged_slots: list[int]):
        return None

    def cow_table(self, slot: int, n_shared: int):
        return None

    def finish_tables(self, slot: int, cow):
        return None

    def staging_table(self, blocks: list[int]):
        raise NotImplementedError("dense substrates share no blocks")

    # --- recurrent state ------------------------------------------------
    def capture_grid(self, prefill_bucket: int) -> int:
        """Boundary grid for prefix-cache snapshots/payloads."""
        return prefill_bucket

    def snapshot(self, caches, row: int = 0):
        """Recurrent-state snapshot at ``row`` (None: nothing to snap)."""
        return None

    def seed_snapshot(self, staging, snap):
        """Swap a snapshot into a staging row (identity when stateless)."""
        return staging

    # --- prefix-cache binding -------------------------------------------
    def prefix_cache_kwargs(self) -> dict:
        """Constructor kwargs binding ``PrefixCache`` to this substrate."""
        return {}

    def prefix_payload(self, prompt: list[int], slot: int, state):
        """THE per-family storage policy: what a finished prefill at
        ``len(prompt)`` contributes to the radix tree, or None.  Returns
        (tokens, blocks, state)."""
        return None


class DenseSlab(CacheBackend):
    """Reference substrate: full per-slot rows, no sharing, no paging."""


class RecurrentState(DenseSlab):
    """Dense O(1)-per-slot recurrent state (ssm): nothing to page, but the
    prefix cache snapshots (conv, ssd) rows at capture-grid boundaries."""

    needs_state = True

    def rollback(self, slot: int, n: int) -> None:
        """Recurrent state has no positions to rewind: a rejected draft's
        contribution is kept OUT of the state rather than removed from it
        — the engine's commit pass re-runs the window from the pre-verify
        tree with dt masked beyond the accept boundary (state frozen,
        rejected tokens contribute exactly zero), which is what the
        fixed-size ``state_snapshot`` machinery already guarantees is
        sufficient to reconstruct any boundary.  Bookkeeping-only here."""
        super().rollback(slot, n)

    def snapshot(self, caches, row: int = 0):
        return self.model.state_snapshot(caches, row)

    def seed_snapshot(self, staging, snap):
        return self.model.seed_from_snapshot(staging, snap)

    def prefix_payload(self, prompt, slot, state):
        if state is None:
            return None
        return (prompt, None, state)


class PagedPool(CacheBackend):
    """Paged-block KV substrate: refcounted fixed-size blocks with per-slot
    block tables; admission reserves ``blocks_needed`` up front so decode
    can never run out mid-request."""

    paged = True

    def __init__(self, model, max_batch: int, max_seq: int,
                 block_size: int, num_blocks: int | None = None):
        self.block_size = block_size
        self.blocks_per_row = ceil_div(max_seq, block_size)
        self.num_blocks = (num_blocks if num_blocks is not None
                           else max_batch * self.blocks_per_row + 1)
        super().__init__(model, max_batch, max_seq,
                         spec=CacheSpec(block_size, self.num_blocks))
        # staged/fresh prefill rows cover whole blocks for the scatter
        self.stage_len = self.blocks_per_row * block_size
        self.allocator = BlockAllocator(self.num_blocks, block_size)
        self.block_tables = np.full(
            (max_batch, self.blocks_per_row), GARBAGE_BLOCK, np.int32)
        self._slot_blocks: list[list[int]] = [[] for _ in range(max_batch)]

    # --- reservation ----------------------------------------------------
    def validate_request(self, rid, prompt_len, max_new):
        need = blocks_needed(prompt_len, max_new, self.max_seq,
                             self.block_size)
        if need > self.num_blocks - 1:
            raise ValueError(
                f"request {rid} needs {need} blocks but the pool "
                f"holds {self.num_blocks - 1}")

    def reservation_need(self, prompt_len, max_new):
        return blocks_needed(prompt_len, max_new, self.max_seq,
                             self.block_size)

    def reserve(self, slot, prompt_len, max_new, shared=None, on_short=None):
        """Claim the request's lifetime block budget up front.  A
        prefix-hit admission refs the ``shared`` blocks (copy-on-write
        share) and allocates only the tail privately; when the pool runs
        short, ``on_short(need)`` may free capacity (prefix-cache LRU
        eviction) before backpressuring.  False = pool short."""
        self._assert_owned()
        shared = list(shared) if shared else []
        need = blocks_needed(prompt_len, max_new, self.max_seq,
                             self.block_size) - len(shared)
        assert need >= 0, (need, len(shared))
        # take the request's ref BEFORE any eviction: the extra owner makes
        # the matched node's blocks non-evictable, so on_short can neither
        # free them nor recycle them as this admission's private tail
        if shared:
            self.allocator.ref(shared)
        if need > self.allocator.free_blocks and on_short is not None:
            on_short(need)
        fresh = self.allocator.alloc(need)
        if fresh is None:
            if shared:
                self.allocator.release(shared)
            return False
        blocks = shared + fresh
        self._slot_blocks[slot] = blocks
        self.block_tables[slot, :] = GARBAGE_BLOCK
        self.block_tables[slot, :len(blocks)] = blocks
        return True

    def free_slot(self, slot):
        self._assert_owned()
        if self._slot_blocks[slot]:
            self.allocator.release(self._slot_blocks[slot])
            self._slot_blocks[slot] = []
            self.block_tables[slot, :] = GARBAGE_BLOCK

    def rollback(self, slot, n):
        """Rejected drafts occupied block-table positions beyond the
        rewound pointer.  Blocks are reserved for the request's LIFETIME
        budget at admission, so nothing is freed and the table is not
        truncated — the rewound positions stay inside the reservation by
        construction (the engine's window clamp), and the junk KV there
        is overwritten as the row re-advances.  Validates the accounting
        instead of mutating it."""
        super().rollback(slot, n)
        assert n == 0 or self._slot_blocks[slot], \
            f"rollback({slot}, {n}) on a slot with no reservation"

    def slot_blocks(self, slot):
        return self._slot_blocks[slot]

    @property
    def free_capacity(self):
        return self.allocator.free_blocks

    @property
    def total_capacity(self):
        return self.num_blocks - 1     # the garbage block is never free

    # --- block tables ---------------------------------------------------
    def admission_tables(self, slots):
        return jnp.asarray(self.block_tables[slots])

    def decode_tables(self, staged_slots):
        """Decode-tick tables.  Mid-admission slots decode masked garbage
        at position 0 — park their rows on the garbage block so the write
        can never land in a reserved block (a warm admission's table starts
        with SHARED prefix blocks, which must never be written in place)."""
        tables = self.block_tables
        if staged_slots:
            tables = tables.copy()
            for slot in staged_slots:
                tables[slot, :] = GARBAGE_BLOCK
        return jnp.asarray(tables)

    def cow_table(self, slot, n_shared):
        """Copy-on-write scatter redirect: the staged scatter's shared
        range lands on the garbage block, private tail blocks stay."""
        table = self.block_tables[slot].copy()
        table[:n_shared] = GARBAGE_BLOCK
        return table

    def finish_tables(self, slot, cow):
        table = cow if cow is not None else self.block_tables[slot]
        return jnp.asarray(table[None])

    def staging_table(self, blocks):
        """(1, blocks_per_row) gather table over ``blocks`` (shared prefix
        in logical order), garbage elsewhere."""
        table = np.full((1, self.blocks_per_row), GARBAGE_BLOCK, np.int32)
        table[0, :len(blocks)] = blocks
        return table

    # --- prefix-cache binding -------------------------------------------
    def capture_grid(self, prefill_bucket):
        return self.block_size

    def prefix_cache_kwargs(self):
        return {"block_size": self.block_size, "backend": self}

    def prefix_payload(self, prompt, slot, state):
        nb = len(prompt) // self.block_size
        if nb == 0:
            return None
        blocks = self._slot_blocks[slot][:nb]
        return (prompt[:nb * self.block_size], blocks, None)

    # --- block ops (the PrefixCache-facing surface) ---------------------
    def ref(self, blocks):
        self.allocator.ref(blocks)

    def release(self, blocks):
        self.allocator.release(blocks)

    def refcount(self, block):
        return self.allocator.refcount(block)

    def writable(self, block):
        return self.allocator.writable(block)

    @property
    def free_blocks(self):
        return self.allocator.free_blocks


class HybridComposite(PagedPool):
    """Split substrate (hybrid): shared-attention KV leaves in the paged
    block pool, O(1) SSM state dense per slot — each leaf gets the
    substrate that pays off.  Prefix boundaries need BOTH halves, so
    payloads exist only at block-aligned prompt lengths."""

    needs_state = True

    def rollback(self, slot, n):
        """Split-substrate rollback composes both halves: the paged
        attention KV beyond the rewound pointer is dead weight inside the
        slot's lifetime reservation (PagedPool semantics), and the
        recurrent half is re-committed by the engine from the pre-verify
        tree with the scan masked at the accept boundary (RecurrentState
        semantics).  The PagedPool accounting check applies."""
        super().rollback(slot, n)

    def snapshot(self, caches, row: int = 0):
        return self.model.state_snapshot(caches, row)

    def seed_snapshot(self, staging, snap):
        return self.model.seed_from_snapshot(staging, snap)

    def prefix_payload(self, prompt, slot, state):
        if state is None or len(prompt) % self.block_size:
            return None
        nb = len(prompt) // self.block_size
        if nb == 0:
            return None
        return (prompt, self._slot_blocks[slot][:nb], state)


def make_backend(model, family: str, config) -> CacheBackend:
    """Pick the substrate for (family, config) — the ONLY place that maps
    families to cache substrates.  ``config`` must already be validated
    against the family (``EngineConfig.validate``)."""
    if config.paged:
        cls = HybridComposite if family in RECURRENT_FAMILIES else PagedPool
        return cls(model, config.max_batch, config.max_seq,
                   block_size=config.block_size,
                   num_blocks=config.num_blocks)
    if family in RECURRENT_FAMILIES:
        return RecurrentState(model, config.max_batch, config.max_seq)
    return DenseSlab(model, config.max_batch, config.max_seq)
