"""Sharded decode attention (flash-decode over the model axis).

Problem (measured in the baseline dry-run): GQA KV caches with few KV heads
(kv=4/8 < model=16) are sequence-sharded, and the decode step's
``dynamic_update_slice`` at a dynamic index forces SPMD to rematerialize the
WHOLE cache every layer (the "involuntary full rematerialization" path) —
the baseline decode cells are collective-bound by TBs of cache traffic.

Fix: run decode attention inside ``shard_map`` over the model axis:
  * each rank owns a contiguous sequence slice of the cache — the new KV
    token is written LOCALLY by the one rank that owns slot ``index``;
  * each rank computes online-softmax partials (m, l, o) over its slice;
  * ranks combine with one tiny ``psum`` of (B, H, dh+2) stats.
Per-step collective traffic drops from O(cache) to O(B x H x dh) — the
flash-decode/ring-attention pattern, expressed as a jax-native shard_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes

NEG = -1e30


def _local_update(cache, new, index, rank, s_shard):
    """Write ``new`` (B,1,...) into the rank-local slice at global ``index``.

    ``index`` may be a scalar (uniform decode depth) or a (B,) array
    (continuous batching: each row writes at its own depth).
    """
    idx = jnp.asarray(index)
    if idx.ndim == 1:
        b = cache.shape[0]
        li = idx - rank * s_shard                      # (B,) local offsets
        in_range = (li >= 0) & (li < s_shard)
        li_c = jnp.clip(li, 0, s_shard - 1)
        rows = jnp.arange(b)
        cur = cache[rows, li_c]
        keep = in_range.reshape((-1,) + (1,) * (cur.ndim - 1))
        return cache.at[rows, li_c].set(
            jnp.where(keep, new[:, 0].astype(cache.dtype), cur))
    li = index - rank * s_shard
    in_range = (li >= 0) & (li < s_shard)
    li_c = jnp.clip(li, 0, s_shard - 1)
    start = (0, li_c) + (0,) * (cache.ndim - 2)
    updated = jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                           start)
    return jnp.where(in_range, updated, cache)


def _valid_cols(cols, idx):
    """(B?, 1, Ss) bool mask of cache columns at or before ``idx``."""
    idx = jnp.asarray(idx)
    if idx.ndim == 1:
        return cols[None, None, :] <= idx[:, None, None]
    return cols[None, None, :] <= idx


def sharded_gqa_decode(q, k_cache, v_cache, k_new, v_new, index, mesh,
                       *, sm_scale: float, grouped_bf16: bool = False):
    """q: (B,1,H,dh); caches: (B,S,Hkv,dh) seq-sharded over 'model';
    k_new/v_new: (B,1,Hkv,dh).  Returns (out (B,1,H,dh), k_cache, v_cache).

    ``grouped_bf16``: skip the f32 KV repeat — GQA-grouped einsums on bf16
    operands with f32 accumulation.  Inside shard_map tensors are local, so
    the (Hkv, g) grouping carries no SPMD-propagation hazard.
    """
    ba = batch_axes(mesh)
    msize = mesh.shape["model"]
    s = k_cache.shape[1]
    s_shard = s // msize
    h = q.shape[2]
    hkv = k_cache.shape[2]
    g = h // hkv

    def per_rank(q, k_c, v_c, k_n, v_n, idx):
        rank = jax.lax.axis_index("model")
        k_c = _local_update(k_c, k_n, idx, rank, s_shard)
        v_c = _local_update(v_c, v_n, idx, rank, s_shard)
        cols = rank * s_shard + jnp.arange(s_shard)
        ok = _valid_cols(cols, idx)
        if grouped_bf16:
            b = q.shape[0]
            qg = q[:, 0].reshape(b, hkv, g, q.shape[-1])      # (B,Hkv,g,dh)
            s_loc = jax.lax.dot_general(                       # (B,Hkv,g,Ss)
                qg, k_c.swapaxes(1, 2),
                (((3,), (3,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32) * sm_scale
            s_loc = s_loc.reshape(b, h, s_shard)
        else:
            kf = jnp.repeat(k_c, g, axis=2).astype(jnp.float32)
            qf = q[:, 0].astype(jnp.float32)
            s_loc = jnp.einsum("bhd,bkhd->bhk", qf, kf) * sm_scale
        s_loc = jnp.where(ok, s_loc, NEG)
        m_loc = jnp.max(s_loc, axis=-1, keepdims=True)        # (B,H,1)
        p = jnp.where(ok, jnp.exp(s_loc - m_loc), 0.0)
        l_loc = jnp.sum(p, axis=-1, keepdims=True)            # (B,H,1)
        if grouped_bf16:
            b = q.shape[0]
            pg = p.reshape(b, hkv, g, s_shard).astype(k_c.dtype)
            o_loc = jax.lax.dot_general(                       # (B,Hkv,g,dh)
                pg, v_c.swapaxes(1, 2),
                (((3,), (2,)), ((0, 1), (0, 1))),
                preferred_element_type=jnp.float32)
            o_loc = o_loc.reshape(b, h, -1)
        else:
            vf = jnp.repeat(v_c, g, axis=2).astype(jnp.float32)
            o_loc = jnp.einsum("bhk,bkhd->bhd", p, vf)        # (B,H,dh)
        # one tiny combine across ranks
        m = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * corr, "model")
        o = jax.lax.psum(o_loc * corr, "model")
        out = (o / jnp.maximum(l, 1e-30)).astype(q.dtype)[:, None]
        return out, k_c, v_c

    cache_spec = P(ba, "model", None, None)
    io_spec = P(ba, None, None, None)
    # a (B,) per-row index is batch-sharded with the tensors it indexes
    idx_spec = P(ba) if getattr(index, "ndim", 0) == 1 else P()
    out, k_cache, v_cache = shard_map(
        per_rank, mesh=mesh,
        in_specs=(io_spec, cache_spec, cache_spec, io_spec, io_spec,
                  idx_spec),
        out_specs=(io_spec, cache_spec, cache_spec),
        check_rep=False,
    )(q, k_cache, v_cache, k_new, v_new, index)
    return out, k_cache, v_cache


def sharded_mla_decode(q_abs, q_rope, c_cache, r_cache, c_new, r_new, index,
                       mesh, *, sm_scale: float):
    """MLA absorbed-form decode with the compressed cache seq-sharded.

    q_abs: (B,1,H,R); q_rope: (B,1,H,dr); c_cache: (B,S,R);
    r_cache: (B,S,dr).  Returns (ctx_c (B,1,H,R), c_cache, r_cache).
    """
    ba = batch_axes(mesh)
    msize = mesh.shape["model"]
    s = c_cache.shape[1]
    s_shard = s // msize

    def per_rank(qa, qr, c_c, r_c, c_n, r_n, idx):
        rank = jax.lax.axis_index("model")
        c_c = _local_update(c_c, c_n, idx, rank, s_shard)
        r_c = _local_update(r_c, r_n, idx, rank, s_shard)
        qa_f = qa[:, 0].astype(jnp.float32)                   # (B,H,R)
        qr_f = qr[:, 0].astype(jnp.float32)                   # (B,H,dr)
        cf = c_c.astype(jnp.float32)                          # (B,Ss,R)
        rf = r_c.astype(jnp.float32)                          # (B,Ss,dr)
        s_loc = (jnp.einsum("bhr,bkr->bhk", qa_f, cf)
                 + jnp.einsum("bhd,bkd->bhk", qr_f, rf)) * sm_scale
        cols = rank * s_shard + jnp.arange(s_shard)
        ok = _valid_cols(cols, idx)
        s_loc = jnp.where(ok, s_loc, NEG)
        m_loc = jnp.max(s_loc, axis=-1, keepdims=True)
        p = jnp.where(ok, jnp.exp(s_loc - m_loc), 0.0)
        l_loc = jnp.sum(p, axis=-1, keepdims=True)
        o_loc = jnp.einsum("bhk,bkr->bhr", p, cf)             # (B,H,R)
        m = jax.lax.pmax(m_loc, "model")
        corr = jnp.exp(m_loc - m)
        l = jax.lax.psum(l_loc * corr, "model")
        o = jax.lax.psum(o_loc * corr, "model")
        ctx = (o / jnp.maximum(l, 1e-30)).astype(qa.dtype)[:, None]
        return ctx, c_c, r_c

    cache_spec = P(ba, "model", None)
    qspec = P(ba, None, None, None)
    idx_spec = P(ba) if getattr(index, "ndim", 0) == 1 else P()
    ctx, c_cache, r_cache = shard_map(
        per_rank, mesh=mesh,
        in_specs=(qspec, qspec, cache_spec, cache_spec,
                  P(ba, None, None), P(ba, None, None), idx_spec),
        out_specs=(qspec, cache_spec, cache_spec),
        check_rep=False,
    )(q_abs, q_rope, c_cache, r_cache, c_new, r_new, index)
    return ctx, c_cache, r_cache
