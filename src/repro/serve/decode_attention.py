"""Sharded decode attention (flash-decode over the model axis).

Problem (measured in the baseline dry-run): GQA KV caches with few KV heads
(kv=4/8 < model=16) are sequence-sharded, and the decode step's
``dynamic_update_slice`` at a dynamic index forces SPMD to rematerialize the
WHOLE cache every layer (the "involuntary full rematerialization" path) —
the baseline decode cells are collective-bound by TBs of cache traffic.

Fix: run decode attention inside ``shard_map`` over the model axis:
  * each rank owns a contiguous sequence slice of the cache — the new KV
    token is written LOCALLY by the one rank that owns slot ``index``;
  * each rank computes online-softmax partials (m, l, o) over its slice;
  * ranks combine with one tiny ``psum`` of (B, H, dh+2) stats.
Per-step collective traffic drops from O(cache) to O(B x H x dh) — the
flash-decode/ring-attention pattern, expressed as a jax-native shard_map.

Paged mode (``block_table`` given): the cache leaves are block pools
(num_blocks, block_size, ...) with no batch axis, sharded over 'model' on
the BLOCK axis.  Batch-shaped inputs stay replicated inside the shard_map
(the pool is shared state — every rank must see every row's write so the
replicas it keeps for foreign blocks never diverge); each rank applies the
writes landing in its block slice, gathers its owned part of each row's
logical view through the table, and combines partials exactly like the
dense path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes

NEG = -1e30


def _local_update(cache, new, index, rank, s_shard):
    """Write ``new`` (B,1,...) into the rank-local slice at global ``index``.

    ``index`` may be a scalar (uniform decode depth) or a (B,) array
    (continuous batching: each row writes at its own depth).
    """
    idx = jnp.asarray(index)
    if idx.ndim == 1:
        b = cache.shape[0]
        li = idx - rank * s_shard                      # (B,) local offsets
        in_range = (li >= 0) & (li < s_shard)
        li_c = jnp.clip(li, 0, s_shard - 1)
        rows = jnp.arange(b)
        cur = cache[rows, li_c]
        keep = in_range.reshape((-1,) + (1,) * (cur.ndim - 1))
        return cache.at[rows, li_c].set(
            jnp.where(keep, new[:, 0].astype(cache.dtype), cur))
    li = index - rank * s_shard
    in_range = (li >= 0) & (li < s_shard)
    li_c = jnp.clip(li, 0, s_shard - 1)
    start = (0, li_c) + (0,) * (cache.ndim - 2)
    updated = jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype),
                                           start)
    return jnp.where(in_range, updated, cache)


def _paged_local_update(pool, new, phys, off, rank, nb_shard):
    """Write ``new`` (B,1,...) into the rank-local block slice.

    ``phys``/``off``: (B,) GLOBAL physical block id and in-block offset of
    each row's write.  Rows whose block another rank owns are routed to the
    out-of-bounds sentinel ``nb_shard`` and dropped by the scatter (OOB
    updates drop; negative indices would wrap, hence the explicit where).
    """
    local = phys - rank * nb_shard
    safe = jnp.where((local >= 0) & (local < nb_shard), local, nb_shard)
    return pool.at[safe, off].set(new[:, 0].astype(pool.dtype), mode="drop")


def _paged_local_view(pool, block_table, rank, nb_shard):
    """Gather each row's logical-order view from the rank-local block slice.

    Returns (view (B, nblk*bs, ...), owned (B, nblk*bs) bool) — columns in
    blocks this rank does not own gather clamped garbage and are masked.
    """
    bs = pool.shape[1]
    local = block_table - rank * nb_shard              # (B, nblk)
    owned = (local >= 0) & (local < nb_shard)
    g = pool[jnp.clip(local, 0, nb_shard - 1)]         # (B, nblk, bs, ...)
    view = g.reshape((block_table.shape[0], -1) + pool.shape[2:])
    return view, jnp.repeat(owned, bs, axis=1)


def _valid_cols(cols, idx):
    """(B?, 1, Ss) bool mask of cache columns at or before ``idx``."""
    idx = jnp.asarray(idx)
    if idx.ndim == 1:
        return cols[None, None, :] <= idx[:, None, None]
    return cols[None, None, :] <= idx


def _combine(m_loc, l_loc, o_loc, dtype):
    """One tiny cross-rank combine of the online-softmax partials."""
    m = jax.lax.pmax(m_loc, "model")
    corr = jnp.exp(m_loc - m)
    denom = jax.lax.psum(l_loc * corr, "model")
    o = jax.lax.psum(o_loc * corr, "model")
    return (o / jnp.maximum(denom, 1e-30)).astype(dtype)[:, None]


def _gqa_partials(q, k_c, v_c, ok, *, g, sm_scale, grouped_bf16):
    """Rank-local online-softmax partials over a (B, Ss, Hkv, dh) KV view.

    ``ok``: (B?, 1, Ss) or (B, 1, Ss) bool validity of each column.
    Returns (m_loc, l_loc, o_loc) each (B, H, ...).
    """
    b, _, h, dh = q.shape
    s_len = k_c.shape[1]
    hkv = k_c.shape[2]
    if grouped_bf16:
        qg = q[:, 0].reshape(b, hkv, g, dh)               # (B,Hkv,g,dh)
        s_loc = jax.lax.dot_general(                       # (B,Hkv,g,Ss)
            qg, k_c.swapaxes(1, 2),
            (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32) * sm_scale
        s_loc = s_loc.reshape(b, h, s_len)
    else:
        kf = jnp.repeat(k_c, g, axis=2).astype(jnp.float32)
        qf = q[:, 0].astype(jnp.float32)
        s_loc = jnp.einsum("bhd,bkhd->bhk", qf, kf) * sm_scale
    s_loc = jnp.where(ok, s_loc, NEG)
    m_loc = jnp.max(s_loc, axis=-1, keepdims=True)        # (B,H,1)
    p = jnp.where(ok, jnp.exp(s_loc - m_loc), 0.0)
    l_loc = jnp.sum(p, axis=-1, keepdims=True)            # (B,H,1)
    if grouped_bf16:
        pg = p.reshape(b, hkv, g, s_len).astype(k_c.dtype)
        o_loc = jax.lax.dot_general(                       # (B,Hkv,g,dh)
            pg, v_c.swapaxes(1, 2),
            (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)
        o_loc = o_loc.reshape(b, h, -1)
    else:
        vf = jnp.repeat(v_c, g, axis=2).astype(jnp.float32)
        o_loc = jnp.einsum("bhk,bkhd->bhd", p, vf)        # (B,H,dh)
    return m_loc, l_loc, o_loc


def sharded_gqa_decode(q, k_cache, v_cache, k_new, v_new, index, mesh,
                       *, sm_scale: float, grouped_bf16: bool = False,
                       block_table=None):
    """q: (B,1,H,dh); k_new/v_new: (B,1,Hkv,dh).

    Dense mode: caches (B,S,Hkv,dh) seq-sharded over 'model'.  Paged mode
    (``block_table`` (B,nblk) given): caches are block pools
    (num_blocks, bs, Hkv, dh) block-sharded over 'model'.  Returns
    (out (B,1,H,dh), k_cache, v_cache).

    ``grouped_bf16``: skip the f32 KV repeat — GQA-grouped einsums on bf16
    operands with f32 accumulation.  Inside shard_map tensors are local, so
    the (Hkv, g) grouping carries no SPMD-propagation hazard.
    """
    ba = batch_axes(mesh)
    msize = mesh.shape["model"]
    b = q.shape[0]
    h = q.shape[2]
    hkv = k_new.shape[2]
    g = h // hkv

    if block_table is not None:
        nb_shard = k_cache.shape[0] // msize
        bs_blk = k_cache.shape[1]
        idx = jnp.asarray(index, jnp.int32) + jnp.zeros((b,), jnp.int32)

        def per_rank(q, k_p, v_p, k_n, v_n, idx, bt):
            rank = jax.lax.axis_index("model")
            rows = jnp.arange(b)
            phys = bt[rows, idx // bs_blk]
            off = idx % bs_blk
            k_p = _paged_local_update(k_p, k_n, phys, off, rank, nb_shard)
            v_p = _paged_local_update(v_p, v_n, phys, off, rank, nb_shard)
            k_c, owned = _paged_local_view(k_p, bt, rank, nb_shard)
            v_c, _ = _paged_local_view(v_p, bt, rank, nb_shard)
            cols = jnp.arange(k_c.shape[1])
            ok = (owned & (cols[None, :] <= idx[:, None]))[:, None]
            m_loc, l_loc, o_loc = _gqa_partials(
                q, k_c, v_c, ok, g=g, sm_scale=sm_scale,
                grouped_bf16=grouped_bf16)
            return _combine(m_loc, l_loc, o_loc, q.dtype), k_p, v_p

        pool_spec = P("model", None, None, None)
        rep = P(None, None, None, None)
        out, k_cache, v_cache = shard_map(
            per_rank, mesh=mesh,
            in_specs=(rep, pool_spec, pool_spec, rep, rep, P(None),
                      P(None, None)),
            out_specs=(rep, pool_spec, pool_spec),
            check_rep=False,
        )(q, k_cache, v_cache, k_new, v_new, idx, block_table)
        return out, k_cache, v_cache

    s = k_cache.shape[1]
    s_shard = s // msize

    def per_rank(q, k_c, v_c, k_n, v_n, idx):
        rank = jax.lax.axis_index("model")
        k_c = _local_update(k_c, k_n, idx, rank, s_shard)
        v_c = _local_update(v_c, v_n, idx, rank, s_shard)
        cols = rank * s_shard + jnp.arange(s_shard)
        ok = _valid_cols(cols, idx)
        m_loc, l_loc, o_loc = _gqa_partials(
            q, k_c, v_c, ok, g=g, sm_scale=sm_scale,
            grouped_bf16=grouped_bf16)
        return _combine(m_loc, l_loc, o_loc, q.dtype), k_c, v_c

    cache_spec = P(ba, "model", None, None)
    io_spec = P(ba, None, None, None)
    # a (B,) per-row index is batch-sharded with the tensors it indexes
    idx_spec = P(ba) if getattr(index, "ndim", 0) == 1 else P()
    out, k_cache, v_cache = shard_map(
        per_rank, mesh=mesh,
        in_specs=(io_spec, cache_spec, cache_spec, io_spec, io_spec,
                  idx_spec),
        out_specs=(io_spec, cache_spec, cache_spec),
        check_rep=False,
    )(q, k_cache, v_cache, k_new, v_new, index)
    return out, k_cache, v_cache


def _mla_partials(qa, qr, c_c, r_c, ok, *, sm_scale):
    """Rank-local partials over a (B, Ss, R)/(B, Ss, dr) compressed view."""
    qa_f = qa[:, 0].astype(jnp.float32)                   # (B,H,R)
    qr_f = qr[:, 0].astype(jnp.float32)                   # (B,H,dr)
    cf = c_c.astype(jnp.float32)                          # (B,Ss,R)
    rf = r_c.astype(jnp.float32)                          # (B,Ss,dr)
    s_loc = (jnp.einsum("bhr,bkr->bhk", qa_f, cf)
             + jnp.einsum("bhd,bkd->bhk", qr_f, rf)) * sm_scale
    s_loc = jnp.where(ok, s_loc, NEG)
    m_loc = jnp.max(s_loc, axis=-1, keepdims=True)
    p = jnp.where(ok, jnp.exp(s_loc - m_loc), 0.0)
    l_loc = jnp.sum(p, axis=-1, keepdims=True)
    o_loc = jnp.einsum("bhk,bkr->bhr", p, cf)             # (B,H,R)
    return m_loc, l_loc, o_loc


def sharded_mla_decode(q_abs, q_rope, c_cache, r_cache, c_new, r_new, index,
                       mesh, *, sm_scale: float, block_table=None):
    """MLA absorbed-form decode with the compressed cache seq-sharded.

    q_abs: (B,1,H,R); q_rope: (B,1,H,dr); dense mode: c_cache (B,S,R) /
    r_cache (B,S,dr); paged mode: (num_blocks, bs, R) / (num_blocks, bs, dr)
    block-sharded over 'model'.  Returns (ctx_c (B,1,H,R), c_cache,
    r_cache).
    """
    ba = batch_axes(mesh)
    msize = mesh.shape["model"]
    b = q_abs.shape[0]

    if block_table is not None:
        nb_shard = c_cache.shape[0] // msize
        bs_blk = c_cache.shape[1]
        idx = jnp.asarray(index, jnp.int32) + jnp.zeros((b,), jnp.int32)

        def per_rank(qa, qr, c_p, r_p, c_n, r_n, idx, bt):
            rank = jax.lax.axis_index("model")
            rows = jnp.arange(b)
            phys = bt[rows, idx // bs_blk]
            off = idx % bs_blk
            c_p = _paged_local_update(c_p, c_n, phys, off, rank, nb_shard)
            r_p = _paged_local_update(r_p, r_n, phys, off, rank, nb_shard)
            c_c, owned = _paged_local_view(c_p, bt, rank, nb_shard)
            r_c, _ = _paged_local_view(r_p, bt, rank, nb_shard)
            cols = jnp.arange(c_c.shape[1])
            ok = (owned & (cols[None, :] <= idx[:, None]))[:, None]
            m_loc, l_loc, o_loc = _mla_partials(qa, qr, c_c, r_c, ok,
                                                sm_scale=sm_scale)
            return _combine(m_loc, l_loc, o_loc, qa.dtype), c_p, r_p

        pool_spec = P("model", None, None)
        qrep = P(None, None, None, None)
        ctx, c_cache, r_cache = shard_map(
            per_rank, mesh=mesh,
            in_specs=(qrep, qrep, pool_spec, pool_spec, P(None, None, None),
                      P(None, None, None), P(None), P(None, None)),
            out_specs=(qrep, pool_spec, pool_spec),
            check_rep=False,
        )(q_abs, q_rope, c_cache, r_cache, c_new, r_new, idx, block_table)
        return ctx, c_cache, r_cache

    s = c_cache.shape[1]
    s_shard = s // msize

    def per_rank(qa, qr, c_c, r_c, c_n, r_n, idx):
        rank = jax.lax.axis_index("model")
        c_c = _local_update(c_c, c_n, idx, rank, s_shard)
        r_c = _local_update(r_c, r_n, idx, rank, s_shard)
        cols = rank * s_shard + jnp.arange(s_shard)
        ok = _valid_cols(cols, idx)
        m_loc, l_loc, o_loc = _mla_partials(qa, qr, c_c, r_c, ok,
                                            sm_scale=sm_scale)
        return _combine(m_loc, l_loc, o_loc, qa.dtype), c_c, r_c

    cache_spec = P(ba, "model", None)
    qspec = P(ba, None, None, None)
    idx_spec = P(ba) if getattr(index, "ndim", 0) == 1 else P()
    ctx, c_cache, r_cache = shard_map(
        per_rank, mesh=mesh,
        in_specs=(qspec, qspec, cache_spec, cache_spec,
                  P(ba, None, None), P(ba, None, None), idx_spec),
        out_specs=(qspec, cache_spec, cache_spec),
        check_rep=False,
    )(q_abs, q_rope, c_cache, r_cache, c_new, r_new, index)
    return ctx, c_cache, r_cache
