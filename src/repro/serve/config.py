"""EngineConfig: every serving knob in one validated dataclass.

Four PRs of engine growth piled ten interdependent kwargs onto
``Engine.__init__`` and scattered their cross-field and family validation
through the constructor.  This module is the single source of truth for
both: the knobs live in one frozen dataclass, the field-level checks run in
``__post_init__``, and the family-dependent rules (which families are
servable, which can page, which need paging for the prefix cache) run in
:meth:`EngineConfig.validate` against the substrate capability sets
declared by ``repro.serve.backend``.

CLI integration is single-sourced too: :meth:`EngineConfig.add_cli_args`
registers the argparse flags and :meth:`EngineConfig.from_args` builds the
config back out of the parsed namespace — both launch CLIs
(``repro.launch.serve`` and ``examples/serve_luna.py``) share them, so a
new knob is added in exactly one place.

Legacy ``Engine(cfg, params, max_batch=..., paged=..., ...)`` kwargs were
removed one release after the v2 API landed (as promised): the engine
constructor takes an :class:`EngineConfig` and nothing else.
"""
from __future__ import annotations

from dataclasses import dataclass, fields, replace

from repro.serve.sampling import SamplingConfig

#: engine-level decode quantization modes (EngineConfig.quant); model-level
#: modes (bf16/int8/luna_* — dynamic per-call quantization via QuantConfig)
#: stay on the model config and share the ``--quant`` CLI flag.  The affine
#: pair (lut4/int4) is token-identical by construction; the non-affine pair
#: (nf4/nf4p) evaluates the NF4 codebook through the least-squares D&C
#: split with a per-code residual correction — full for nf4, pruned below
#: a magnitude threshold for nf4p (table capacity vs bounded accuracy).
ENGINE_QUANT_MODES = ("lut4", "int4", "nf4", "nf4p")

#: speculative-decoding draft proposers (EngineConfig.spec).  "ngram" is
#: prompt-lookup drafting (no extra weights: the longest context-suffix
#: n-gram is matched against earlier prompt+output text and its
#: continuation proposed); "self_lut" is self-speculation — the SAME model
#: runs its decode step over pruned-LUT ``nf4p`` weights as the cheap
#: drafter while full precision verifies (LoCalut's capacity-computation
#: tradeoff applied to serving).  See ``docs/speculative.md``.
ENGINE_SPEC_MODES = ("ngram", "self_lut")


@dataclass(frozen=True)
class EngineConfig:
    """All engine knobs; see the README "Serving engine" section.

    * ``max_batch`` / ``max_seq`` — slot count and per-slot token budget.
    * ``prefill_bucket`` — prompt lengths are padded up to multiples of
      this and prefilled one jit call per bucket.
    * ``paged`` / ``block_size`` / ``num_blocks`` — paged-block KV cache
      (attention families): per-request block reservation instead of full
      ``max_seq`` rows; ``num_blocks=None`` sizes the pool at
      dense-equivalent capacity plus the reserved garbage block.
    * ``prefill_chunk`` — admit prompts longer than this in N-token chunks
      interleaved with decode ticks.
    * ``prefix_cache`` / ``prefix_cache_nodes`` — radix-tree prompt-prefix
      reuse (attention families require ``paged=True``).
    * ``sampling`` / ``seed`` — token sampling mode and the engine PRNG
      seed (``sampling=None`` means greedy).
    * ``starvation_bound`` — scheduler aging threshold: a queued request
      passed over this many times gains one priority bucket (see
      ``repro.serve.engine.Scheduler``).
    * ``idle_backoff_s`` — background serve loop (``engine.start()``):
      how long the loop thread sleeps when there is no queued, staged, or
      active work before re-checking (a ``submit()``/``cancel()`` wakes it
      immediately, so this only bounds shutdown latency and idle spin).
    * ``quant`` — decode weight quantization: ``"lut4"`` freezes decode
      projections to 4-bit codes evaluated through the paper's D&C
      sub-table LUT GEMM, ``"int4"`` is the direct-dequant baseline
      (token-identical math, conventional evaluation), ``"nf4"`` encodes
      against the non-affine NF4 codebook and evaluates it as the
      least-squares D&C split plus a per-code residual correction,
      ``"nf4p"`` prunes that residual below a magnitude threshold (smaller
      tables, bounded accuracy cost), ``None`` keeps bf16 decode
      token-identical to prior releases.  Prefill always runs full
      precision; see ``docs/quantization.md``.
    * ``trace`` / ``trace_buffer`` — request-lifecycle tracing
      (``repro.obs``): record clock-stamped span events into a ring
      buffer of ``trace_buffer`` events, exportable as Perfetto JSON.
      Off by default (a disabled tracer is a cheap early-return); see
      ``docs/observability.md``.
    * ``spec`` / ``spec_k`` — speculative decoding: each tick drafts up
      to ``spec_k`` tokens per active request (``"ngram"`` prompt-lookup
      or ``"self_lut"`` self-speculation over nf4p LUT weights), scores
      the whole window in ONE batched verify pass, emits the accepted
      prefix plus the verifier's correction, and rolls back the rest.
      Greedy-only (acceptance is pinned token-identical to
      non-speculative greedy); see ``docs/speculative.md``.
    """
    max_batch: int = 8
    max_seq: int = 256
    prefill_bucket: int = 16
    paged: bool = False
    block_size: int = 16
    num_blocks: int | None = None
    prefill_chunk: int | None = None
    prefix_cache: bool = False
    prefix_cache_nodes: int = 256
    sampling: SamplingConfig | None = None
    seed: int = 0
    starvation_bound: int = 8
    quant: str | None = None
    idle_backoff_s: float = 0.002
    trace: bool = False
    trace_buffer: int = 65536
    spec: str | None = None
    spec_k: int = 4

    def __post_init__(self):
        if self.quant is not None and self.quant not in ENGINE_QUANT_MODES:
            raise ValueError(
                f"quant must be one of {ENGINE_QUANT_MODES} or None, "
                f"got {self.quant!r}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_seq < 2:
            raise ValueError(f"max_seq must be >= 2 (one prompt token + one "
                             f"generated), got {self.max_seq}")
        if self.prefill_bucket < 1:
            raise ValueError(f"prefill_bucket must be >= 1, "
                             f"got {self.prefill_bucket}")
        if self.prefill_chunk is not None and self.prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, "
                             f"got {self.prefill_chunk}")
        if self.paged and self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, "
                             f"got {self.block_size}")
        if self.prefix_cache and self.prefix_cache_nodes < 1:
            raise ValueError(f"prefix_cache_nodes must be >= 1, "
                             f"got {self.prefix_cache_nodes}")
        if self.starvation_bound < 1:
            raise ValueError(f"starvation_bound must be >= 1, "
                             f"got {self.starvation_bound}")
        if self.idle_backoff_s < 0:
            raise ValueError(f"idle_backoff_s must be >= 0, "
                             f"got {self.idle_backoff_s}")
        if self.trace_buffer < 1:
            raise ValueError(f"trace_buffer must be >= 1, "
                             f"got {self.trace_buffer}")
        if self.spec is not None and self.spec not in ENGINE_SPEC_MODES:
            raise ValueError(
                f"spec must be one of {ENGINE_SPEC_MODES} or None, "
                f"got {self.spec!r}")
        if self.spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {self.spec_k}")
        if self.spec is not None and self.sampling is not None \
                and self.sampling.mode != "greedy":
            raise ValueError(
                "speculative decoding is greedy-only (acceptance is pinned "
                "token-identical to non-speculative greedy argmax); got "
                f"spec={self.spec!r} with sampling mode "
                f"{self.sampling.mode!r}")

    # --- family cross-validation ----------------------------------------
    def validate(self, family: str) -> None:
        """Every family-dependent rule, in one place (previously scattered
        through ``Engine.__init__``)."""
        from repro.serve.backend import PAGED_FAMILIES, SERVED_FAMILIES
        if family in ("encdec", "vlm"):
            raise ValueError(
                f"family {family!r} needs modality inputs the text-only "
                "engine does not carry")
        if family not in SERVED_FAMILIES:
            raise ValueError(
                f"family {family!r} is not servable by this engine "
                f"(supported: {SERVED_FAMILIES})")
        if self.paged and family not in PAGED_FAMILIES:
            raise ValueError(
                f"paged=True is not supported for family {family!r}: "
                "its cache is O(1) recurrent state per slot with no KV "
                f"leaves to page (paged families: {PAGED_FAMILIES})")
        if self.prefix_cache and family in PAGED_FAMILIES and not self.paged:
            raise ValueError(
                f"prefix_cache for family {family!r} shares its "
                "attention KV as copy-on-write paged blocks — construct "
                "with paged=True (the ssm family caches dense state "
                "snapshots and needs no paging)")

    # --- CLI binding ----------------------------------------------------
    @staticmethod
    def add_cli_args(ap) -> None:
        """Register the shared engine flags on an argparse parser."""
        ap.add_argument("--max-batch", type=int, default=None,
                        help="concurrent sequence slots")
        ap.add_argument("--max-seq", type=int, default=None,
                        help="per-slot token budget (prompt + generation)")
        ap.add_argument("--prefill-bucket", type=int, default=None,
                        help="prompt lengths are padded up to multiples of "
                             "this and prefilled one jit call per bucket")
        ap.add_argument("--paged", action="store_true",
                        help="paged-block KV cache: per-request block "
                             "reservation instead of full max-seq rows "
                             "(attention families)")
        ap.add_argument("--block-size", type=int, default=None,
                        help="tokens per KV block in --paged mode")
        ap.add_argument("--num-blocks", type=int, default=None,
                        help="pool size in blocks (default: dense-equivalent "
                             "capacity + the reserved garbage block)")
        ap.add_argument("--prefill-chunk", type=int, default=None,
                        help="admit prompts longer than this in N-token "
                             "chunks interleaved with decode ticks")
        ap.add_argument("--prefix-cache", action="store_true",
                        help="radix-tree prompt-prefix sharing: warm "
                             "admissions reuse cached KV blocks (attention, "
                             "needs --paged) or recurrent state snapshots "
                             "(ssm) and prefill only the uncached tail")
        ap.add_argument("--prefix-cache-nodes", type=int, default=None,
                        help="LRU budget for cached prefix boundaries")
        ap.add_argument("--idle-backoff-s", type=float, default=None,
                        help="background serve loop: idle sleep between "
                             "re-checks when no work is pending")
        ap.add_argument("--trace", action="store_true",
                        help="record request-lifecycle + engine-phase trace "
                             "events (ring-buffered; export with "
                             "--trace-out)")
        ap.add_argument("--trace-buffer", type=int, default=None,
                        help="trace ring-buffer capacity in events "
                             "(oldest dropped on overflow)")
        ap.add_argument("--trace-out", default=None, metavar="PATH",
                        help="write the trace as Perfetto/Chrome "
                             "trace_event JSON on exit (implies --trace); "
                             "open at https://ui.perfetto.dev")
        ap.add_argument("--metrics-port", type=int, default=None,
                        help="serve the metrics registry at "
                             "http://127.0.0.1:PORT/metrics (Prometheus "
                             "text exposition) from a background thread")
        ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                        help="write the Prometheus text exposition to PATH "
                             "on exit")
        ap.add_argument("--spec", default=None,
                        choices=list(ENGINE_SPEC_MODES),
                        help="speculative decoding draft proposer: 'ngram' "
                             "(prompt-lookup, no extra weights) or "
                             "'self_lut' (self-speculation: the same model "
                             "over pruned nf4p LUT weights drafts, full "
                             "precision verifies); greedy-only")
        ap.add_argument("--spec-k", type=int, default=None,
                        help="max draft tokens per request per tick "
                             "(speculation window = spec_k + 1)")
        ap.add_argument("--sampling", default="greedy",
                        choices=["greedy", "temperature", "top_k"])
        ap.add_argument("--temperature", type=float, default=1.0)
        ap.add_argument("--top-k", type=int, default=40)
        ap.add_argument("--seed", type=int, default=0)
        ap.add_argument("--quant", default=None,
                        help="weight quantization: 'lut4' (4-bit decode "
                             "weights through the D&C sub-table LUT gemm), "
                             "'int4' (direct-dequant baseline), 'nf4' "
                             "(non-affine NF4 codebook, D&C + residual "
                             "correction) or 'nf4p' (pruned residual sub-"
                             "table) quantize the DECODE hot path at "
                             "engine construction; any other value (bf16, "
                             "int8, int4_dequant, lut_nf4, luna_*) is a "
                             "model-level mode applied dynamically to "
                             "every projection")

    @classmethod
    def from_args(cls, args, **overrides) -> "EngineConfig":
        """Build a config from an argparse namespace produced by
        :meth:`add_cli_args`.  ``overrides`` win over CLI values (a CLI may
        pin e.g. ``max_batch`` instead of exposing the flag); flags the
        parser left at None fall back to the dataclass defaults.  The
        shared ``--quant`` flag reaches ``EngineConfig.quant`` only for
        engine-level modes — model-level spellings (bf16/luna_*/...) are
        the caller's to route into a ``QuantConfig`` and leave the engine
        field at None."""
        cfg = cls()
        vals = {}
        for f in fields(cls):
            if f.name in ("sampling", "quant"):
                continue
            v = getattr(args, f.name, None)
            if v is not None and v is not False:
                vals[f.name] = v
        q = getattr(args, "quant", None)
        if q in ENGINE_QUANT_MODES:
            vals["quant"] = q
        if getattr(args, "trace_out", None):
            vals["trace"] = True       # a trace sink implies recording
        mode = getattr(args, "sampling", "greedy")
        vals["sampling"] = SamplingConfig(
            mode=mode, temperature=getattr(args, "temperature", 1.0),
            top_k=getattr(args, "top_k", 0) if mode == "top_k" else 0)
        vals.update(overrides)
        return replace(cfg, **vals)
