"""Host-side block accounting for the paged KV cache.

The engine's cache slab becomes a pool of ``num_blocks`` fixed-size blocks
of ``block_size`` tokens each.  A request owns only the blocks its
prompt + generation budget needs; freeing a slot returns its blocks to the
pool (no full ``max_seq`` row rewrites).  The device-side gather/scatter
lives in ``repro.models.common`` (:func:`paged_gather` /
:func:`paged_write`); this module is the pure-python allocator the engine
drives between jit calls.

Physical block 0 is reserved as the *garbage block*: free decode lanes and
unreserved block-table entries point at it, so every lane always has a
legal write target and reads from it are masked by the per-row ``kv_len``.
"""
from __future__ import annotations

GARBAGE_BLOCK = 0


def blocks_needed(prompt_len: int, max_new: int, max_seq: int,
                  block_size: int) -> int:
    """Blocks a request needs for its whole lifetime (prompt + decode),
    reserved at admission so decode can never run out mid-request."""
    return -(-min(prompt_len + max_new, max_seq) // block_size)


class BlockAllocator:
    """Free-list over ``num_blocks`` blocks; block 0 is never handed out."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved garbage "
                             f"block), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list; block 0 (garbage) is never in it
        self._free = list(range(num_blocks - 1, GARBAGE_BLOCK, -1))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` blocks, or None (and no change) if the pool is short."""
        if n < 0 or n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def release(self, blocks: list[int]) -> None:
        for b in blocks:
            assert GARBAGE_BLOCK < b < self.num_blocks, b
            assert b not in self._free, f"double free of block {b}"
            self._free.append(b)
