"""Host-side block accounting for the paged KV cache.

The engine's cache slab becomes a pool of ``num_blocks`` fixed-size blocks
of ``block_size`` tokens each.  A request owns only the blocks its
prompt + generation budget needs; freeing a slot returns its blocks to the
pool (no full ``max_seq`` row rewrites).  The device-side gather/scatter
lives in ``repro.models.common`` (:func:`paged_gather` /
:func:`paged_write`); this module is the pure-python allocator the engine
drives between jit calls.

Blocks are REFCOUNTED so the prefix cache (``repro.serve.prefix_cache``)
can share one physical copy of a common prompt head across many owners: a
block's count is the number of owners holding it (each admitted request's
block table, plus each radix-tree node caching it).  ``alloc`` hands out
count-1 blocks; ``ref`` adds an owner; ``release`` drops one and the block
only returns to the free pool when its LAST owner lets go.  Copy-on-write
discipline: a block with more than one owner must never be written in
place (``writable`` is the predicate) — the engine redirects shared-range
scatter writes to the garbage block and recomputes divergent tails into
freshly-allocated private blocks.

Physical block 0 is reserved as the *garbage block*: free decode lanes and
unreserved block-table entries point at it, so every lane always has a
legal write target and reads from it are masked by the per-row ``kv_len``.
"""
from __future__ import annotations

GARBAGE_BLOCK = 0


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def blocks_needed(prompt_len: int, max_new: int, max_seq: int,
                  block_size: int) -> int:
    """Blocks a request needs for its whole lifetime (prompt + decode),
    reserved at admission so decode can never run out mid-request.  The one
    source of truth — the engine and the prefix cache both call this."""
    return ceil_div(min(prompt_len + max_new, max_seq), block_size)


class BlockAllocator:
    """Refcounted free-list over ``num_blocks`` blocks; block 0 is never
    handed out.  ``alloc``/``release`` are O(1) per block: the LIFO free
    list is mirrored by a free-SET so the no-double-free invariant check
    does not scan the list (refcounted sharing multiplies release traffic —
    every cached prefix adds an owner whose release must stay cheap)."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (one is the reserved garbage "
                             f"block), got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list; block 0 (garbage) is never in it
        self._free = list(range(num_blocks - 1, GARBAGE_BLOCK, -1))
        self._free_set = set(self._free)
        self._refs = [0] * num_blocks

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - 1 - len(self._free)

    def refcount(self, block: int) -> int:
        """Number of owners currently holding ``block``."""
        return self._refs[block]

    def writable(self, block: int) -> bool:
        """Copy-on-write predicate: only a sole owner may write in place."""
        return self._refs[block] == 1

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` blocks at refcount 1, or None (and no change) if the
        pool is short."""
        if n < 0 or n > len(self._free):
            return None
        out = []
        for _ in range(n):
            b = self._free.pop()
            self._free_set.discard(b)
            self._refs[b] = 1
            out.append(b)
        return out

    def ref(self, blocks: list[int]) -> None:
        """Add an owner to already-held blocks (prefix sharing)."""
        for b in blocks:
            assert GARBAGE_BLOCK < b < self.num_blocks, b
            assert self._refs[b] > 0, f"ref of unheld block {b}"
            self._refs[b] += 1

    def release(self, blocks: list[int]) -> None:
        """Drop one owner per block; a block returns to the free pool only
        when its refcount reaches 0 (never earlier — cached copies survive
        the request that built them)."""
        for b in blocks:
            assert GARBAGE_BLOCK < b < self.num_blocks, b
            assert b not in self._free_set, f"double free of block {b}"
            assert self._refs[b] > 0, f"release of unheld block {b}"
            self._refs[b] -= 1
            if self._refs[b] == 0:
                self._free.append(b)
                self._free_set.add(b)
