"""Speculative decoding: draft proposers + the accept/rollback contract.

The engine's speculative tick is draft -> batched-verify -> accept-prefix
-> rollback:

  * **draft** — a :class:`DraftProposer` guesses up to ``spec_k`` next
    tokens per active slot (host-side n-gram lookup, or the model itself
    over pruned-LUT ``nf4p`` weights);
  * **verify** — the FULL-precision model scores the whole window
    ``[last_emitted, d_1 .. d_k]`` in one batched ``decode_window`` call;
    ``argmax(logits[:, i])`` is the greedy token after window column
    ``i``, exactly what non-speculative decode would have produced;
  * **accept-prefix** — drafts are accepted left-to-right while they
    match the verifier's argmax (:func:`accept_length`); the first
    mismatch position still yields one emitted token — the verifier's own
    correction — so every tick emits ``accepted + 1`` tokens and the
    output stream is token-identical to non-speculative greedy;
  * **rollback** — rejected positions are undone per substrate: attention
    KV beyond the rewound pointer is dead weight the next writes
    overwrite (``CacheBackend.rollback`` is pure bookkeeping); recurrent
    state cannot rewind, so the engine re-commits it from the pre-verify
    cache tree with the SSD scan masked at the accept boundary (see
    ``Engine._spec_tick``); hybrid composes both.

Proposers return plain host-side token lists; correctness never depends
on draft quality — a bad draft only costs the wasted verify columns.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def accept_length(drafts, targets) -> int:
    """Length of the accepted draft prefix.

    ``targets[i]`` is the verifier's greedy token after window column
    ``i`` — i.e. the token that SHOULD follow ``drafts[:i]``.  Draft ``i``
    is accepted iff it equals ``targets[i]``; the scan stops at the first
    mismatch (later agreements are conditioned on a wrong prefix and
    worthless).
    """
    m = 0
    for i, d in enumerate(drafts):
        if int(d) != int(targets[i]):
            break
        m += 1
    return m


class DraftProposer:
    """Protocol: guess the next tokens of every active slot.

    ``propose(reqs, k_eff)`` takes the per-slot request list (``None`` for
    empty/staged slots) and per-slot draft budgets, and returns per-slot
    token lists with ``len(drafts[s]) <= k_eff[s]``.  Proposals are pure
    suggestions — the engine verifies every one at full precision, so a
    proposer can be arbitrarily wrong without affecting output tokens.
    """

    name = "base"

    def propose(self, reqs, k_eff) -> list[list[int]]:
        raise NotImplementedError


class NGramProposer(DraftProposer):
    """Prompt-lookup decoding: draft from the request's own history.

    The longest n-gram suffix (``max_ngram`` down to ``min_ngram``) of
    ``prompt + out`` is matched against the most recent earlier occurrence
    in the same text; the tokens that followed it are proposed.  No extra
    weights, no device work — pure host-side list scanning, so it rides
    along with any quant mode and any family.
    """

    name = "ngram"

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        assert 1 <= min_ngram <= max_ngram, (min_ngram, max_ngram)
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, reqs, k_eff):
        out = []
        for req, k in zip(reqs, k_eff):
            if req is None or k <= 0:
                out.append([])
                continue
            ctx = list(req.prompt) + list(req.out)
            out.append(_prompt_lookup(ctx, int(k), self.max_ngram,
                                      self.min_ngram))
        return out


def _prompt_lookup(ctx: list[int], k: int, max_n: int, min_n: int
                   ) -> list[int]:
    """Continuation of the most recent earlier match of the longest
    context-suffix n-gram; [] when nothing matches."""
    n_ctx = len(ctx)
    for n in range(min(max_n, n_ctx - 1), min_n - 1, -1):
        suffix = ctx[-n:]
        for j in range(n_ctx - n - 1, -1, -1):
            if ctx[j:j + n] == suffix:
                cont = ctx[j + n:j + n + k]
                if cont:
                    return [int(t) for t in cont]
                break        # the match is flush with the suffix: shorter n
    return []


class SelfLutProposer(DraftProposer):
    """Self-speculation over the pruned-LUT draft tree.

    ``spec_k`` sequential greedy steps of the engine's jitted draft step
    (``decode_step`` over ``nf4p``-quantized weights) run against a LOCAL
    functional copy of the live caches — the copy is discarded, so draft
    writes land harmlessly anywhere (staged rows stay parked on the
    garbage block; prefix-cache COW blocks are never written because
    draft steps use the same ``decode_tables`` view decode uses).  All
    ``max_batch`` rows step together; rows past their own ``k_eff`` just
    produce ignored tokens.
    """

    name = "self_lut"

    def __init__(self, engine):
        self.engine = engine

    def propose(self, reqs, k_eff):
        eng = self.engine
        kmax = max((int(k) for r, k in zip(reqs, k_eff) if r is not None),
                   default=0)
        drafts: list[list[int]] = [[] for _ in reqs]
        if kmax <= 0:
            return drafts
        toks = np.zeros((eng.max_batch, 1), np.int32)
        for s, req in enumerate(reqs):
            if req is not None:
                toks[s, 0] = req.out[-1]
        positions = np.asarray(eng.positions, np.int64).copy()
        caches = eng.caches                       # functional copy-on-write
        tables = eng.backend.decode_tables([cp.slot for cp in eng._chunked])
        for _ in range(kmax):
            pos = np.minimum(positions, eng.max_seq - 1).astype(np.int32)
            nxt, caches = eng._draft(eng.draft_params, jnp.asarray(toks),
                                     caches, jnp.asarray(pos), tables)
            nxt = np.asarray(nxt)
            for s, req in enumerate(reqs):
                if req is not None and len(drafts[s]) < int(k_eff[s]):
                    drafts[s].append(int(nxt[s]))
            toks[:, 0] = nxt
            positions += 1
        return drafts


def make_proposer(mode: str, engine) -> DraftProposer:
    """EngineConfig(spec=...) -> proposer instance bound to the engine."""
    if mode == "ngram":
        return NGramProposer()
    if mode == "self_lut":
        return SelfLutProposer(engine)
    raise ValueError(f"unknown spec mode {mode!r} "
                     "(expected 'ngram' or 'self_lut')")
