"""Prefix cache: a radix tree over prompt tokens with two storage backends.

LUNA's thesis is that *reuse beats recomputation* — serving traffic makes
the same bet at the request level: million-user workloads lead with a
shared system-prompt head, so the engine should pay its prefill cost once
and look the result up afterwards.  This module is the host-side index for
that lookup; the engine (``repro.serve.engine``) drives it at admission.

Tree structure
--------------
A compressed radix tree: each node's ``edge`` is the token run from its
parent, ``depth`` is the total prefix length ending at the node.  Inserting
a prompt that diverges mid-edge SPLITS the edge; matching walks whole edges
only (a partial edge never yields a payload — the next insert materializes
the split point, and later requests hit it).

Node payloads (either or both, per serving family):

* ``blocks`` — physical ids of the paged-pool blocks holding this prefix's
  attention KV, ``floor(depth / block_size)`` of them (whole blocks only).
  The cache co-owns them through the backend's block refcounts; an admission
  that matches shares them COPY-ON-WRITE into the request's block table —
  the request refs them, reads them in place, and never writes them (tail
  writes land in freshly-allocated private blocks; the engine redirects the
  shared range of its prefill scatter to the garbage block).  When a node
  is split, the new internal node derives ``blocks[:mid_depth // bs]`` from
  its child — a shared HEAD becomes matchable the moment the first
  divergent request is inserted.
* ``state`` — the recurrent families' fixed-size dense snapshot
  (conv_state, ssd_state) captured AT ``depth`` from the state-continuing
  SSD scan.  Unlike attention KV, recurrent state cannot be truncated: a
  snapshot serves exactly its own boundary, so matching returns the deepest
  node whose snapshot depth fits.

Eviction is LRU over leaf nodes.  When the block pool runs short
(``evict_for``), only *unreferenced* leaves count — nodes whose blocks no
active request shares (backend refcount == the cache's own holds); blocks
return to the free pool strictly at refcount 0, so eviction can never yank
a page out from under a live block table.  The node-budget trim
(``max_nodes``, bounding snapshot memory) may drop any LRU leaf — request
refs keep shared block content alive regardless.
"""
from __future__ import annotations

from dataclasses import dataclass, field


class _Node:
    __slots__ = ("parent", "edge", "children", "depth", "blocks", "state",
                 "last_used")

    def __init__(self, parent: "_Node | None", edge: tuple[int, ...],
                 depth: int):
        self.parent = parent
        self.edge = edge
        self.children: dict[int, _Node] = {}
        self.depth = depth
        self.blocks: list[int] | None = None
        self.state = None
        self.last_used = 0


@dataclass
class PrefixHit:
    """One admission-time match: reuse ``length`` prompt tokens."""
    length: int                       # tokens of prefill skipped
    blocks: list[int] = field(default_factory=list)   # shared COW blocks
    state: object | None = None       # recurrent snapshot at ``length``


class PrefixCache:
    """Radix tree + payload store.  ``block_size``/``backend`` bind the
    paged substrate: ``backend`` is any object exposing the narrow block-op
    surface ``ref(blocks)`` / ``release(blocks)`` / ``refcount(block)`` /
    ``free_blocks`` (a ``repro.serve.backend.PagedPool`` in the engine; a
    raw ``BlockAllocator`` satisfies the same protocol in tests).  Leave
    both None for the pure recurrent-state backend (mamba2's dense
    engine)."""

    def __init__(self, *, block_size: int | None = None,
                 backend=None, max_nodes: int = 256):
        assert (block_size is None) == (backend is None)
        if max_nodes < 1:
            raise ValueError(f"max_nodes must be >= 1, got {max_nodes}")
        self.block_size = block_size
        self.backend = backend
        self.max_nodes = max_nodes
        self._root = _Node(None, (), 0)
        self._tick = 0
        self.node_count = 0
        self.evictions = 0            # lifetime total (engine metrics diff)
        self.lookups = 0              # lifetime match() calls
        self.hits = 0                 # lifetime match() calls that hit
        # cache-side owner count per block id: how many node payloads hold
        # it.  backend.refcount(b) == _block_owners[b] <=> no live request
        # shares b, which is what pool-shortage eviction needs to know.
        self._block_owners: dict[int, int] = {}

    # --- matching -------------------------------------------------------
    def match(self, tokens: list[int], *, max_len: int,
              need_state: bool = False) -> PrefixHit | None:
        """Longest cached prefix of ``tokens`` usable at admission.

        ``max_len`` caps the reused length (the engine passes
        ``len(prompt) - 1`` — at least one tail token must run through
        prefill to produce the last-position logits).  ``need_state``:
        recurrent families need a snapshot AT the boundary; attention-only
        families can take any whole-block prefix of a deeper node's blocks.
        """
        self._tick += 1
        self.lookups += 1
        node, depth, best = self._root, 0, None
        while True:
            hit = self._usable(node, max_len, need_state)
            if hit is not None:
                best = (node, hit)
            if depth >= len(tokens):
                break
            child = node.children.get(tokens[depth])
            if child is None:
                break
            e = child.edge
            rest = tuple(tokens[depth:depth + len(e)])
            if rest != e:
                # partial edge: no state boundary lives mid-edge, but the
                # matched span's whole blocks ARE usable — token equality
                # is verified up to depth+m and a block list truncates
                # cleanly (the shared-system-prompt case: the first
                # divergent request reuses the head before any split
                # materializes it as a node)
                m = _common_len(e, rest)
                part = self._partial(child, depth + m, max_len, need_state)
                if part is not None and (best is None
                                         or part.length > best[1].length):
                    best = (child, part)
                break
            node, depth = child, depth + len(e)
        if best is None:
            return None
        node, hit = best
        self.hits += 1
        n = node
        while n is not None:          # refresh the whole hit path's LRU age
            n.last_used = self._tick
            n = n.parent
        return hit

    def _partial(self, child: _Node, matched: int, max_len: int,
                 need_state: bool) -> PrefixHit | None:
        """Blocks-only hit from a partially-matched edge: ``matched``
        tokens of the prefix ending at ``child`` are verified equal."""
        if need_state or self.block_size is None or child.blocks is None:
            return None
        nb = min(len(child.blocks), matched // self.block_size,
                 max_len // self.block_size)
        if nb < 1:
            return None
        return PrefixHit(nb * self.block_size, list(child.blocks[:nb]), None)

    def _usable(self, node: _Node, max_len: int,
                need_state: bool) -> PrefixHit | None:
        if node is self._root:
            return None
        if need_state:
            if node.state is None or node.depth > max_len:
                return None
            if self.block_size is not None:
                # hybrid: the boundary needs blocks covering [0, depth)
                if (node.blocks is None or node.depth % self.block_size
                        or len(node.blocks) * self.block_size < node.depth):
                    return None
                return PrefixHit(node.depth,
                                 list(node.blocks[:node.depth
                                                  // self.block_size]),
                                 node.state)
            return PrefixHit(node.depth, [], node.state)
        if node.blocks is None or self.block_size is None:
            return None
        nb = min(len(node.blocks), max_len // self.block_size)
        if nb < 1:
            return None
        return PrefixHit(nb * self.block_size, list(node.blocks[:nb]), None)

    # --- insertion ------------------------------------------------------
    def insert(self, tokens: list[int], *, blocks: list[int] | None = None,
               state=None) -> None:
        """Cache a payload at boundary ``len(tokens)``.  ``blocks`` are the
        request's own pool blocks for [0, len(tokens)) — the cache becomes
        a co-owner (refs them); existing payloads at the boundary are kept
        (first writer wins: both copies are equally valid and re-refing
        would leak)."""
        if not tokens or (blocks is None and state is None):
            return
        self._tick += 1
        node, depth = self._root, 0
        while depth < len(tokens):
            child = node.children.get(tokens[depth])
            if child is None:
                new = _Node(node, tuple(tokens[depth:]), len(tokens))
                node.children[tokens[depth]] = new
                self.node_count += 1
                node, depth = new, len(tokens)
                break
            e = child.edge
            rest = tuple(tokens[depth:depth + len(e)])
            m = _common_len(e, rest)
            if m == len(e):
                node, depth = child, depth + len(e)
                continue
            node, depth = self._split(child, m), depth + m
        assert node.depth == len(tokens), (node.depth, len(tokens))
        if blocks is not None and node.blocks is None and self.block_size:
            keep = list(blocks[:len(tokens) // self.block_size])
            if keep:
                self.backend.ref(keep)
                self._own(keep, +1)
                node.blocks = keep
        if state is not None and node.state is None:
            node.state = state
        node.last_used = self._tick
        self.trim()

    def _split(self, child: _Node, m: int) -> _Node:
        """Split ``child``'s edge after ``m`` tokens; the new internal node
        derives the whole-block prefix of the child's payload so the shared
        head is immediately matchable."""
        assert 0 < m < len(child.edge)
        parent = child.parent
        mid = _Node(parent, child.edge[:m], child.depth - len(child.edge) + m)
        parent.children[child.edge[0]] = mid
        child.edge = child.edge[m:]
        child.parent = mid
        mid.children[child.edge[0]] = child
        mid.last_used = child.last_used
        if child.blocks is not None and self.block_size is not None:
            derived = list(child.blocks[:mid.depth // self.block_size])
            if derived:
                self.backend.ref(derived)
                self._own(derived, +1)
                mid.blocks = derived
        self.node_count += 1
        return mid

    # --- eviction -------------------------------------------------------
    def evict_for(self, n_blocks: int) -> int:
        """Pool shortage: evict LRU *unreferenced* leaves until the
        backend can hand out ``n_blocks`` (or no candidate remains).
        Returns the number of nodes evicted."""
        if self.backend is None:
            return 0
        count = 0
        while self.backend.free_blocks < n_blocks:
            victim = self._lru_leaf(unreferenced_only=True)
            if victim is None:
                break
            self._evict(victim)
            count += 1
        return count

    def trim(self) -> int:
        """Node-budget eviction (bounds recurrent-snapshot memory)."""
        count = 0
        while self.node_count > self.max_nodes:
            victim = self._lru_leaf(unreferenced_only=False)
            if victim is None:
                break
            self._evict(victim)
            count += 1
        return count

    def _leaves(self):
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                yield n

    def _unreferenced(self, node: _Node) -> bool:
        """No live request co-owns this node's blocks: every ref is
        accounted for by cache-node payloads."""
        if node.blocks is None:
            return True
        return all(self.backend.refcount(b) == self._block_owners.get(b, 0)
                   for b in node.blocks)

    def _lru_leaf(self, *, unreferenced_only: bool) -> _Node | None:
        best = None
        for n in self._leaves():
            if unreferenced_only and not self._unreferenced(n):
                continue
            if best is None or n.last_used < best.last_used:
                best = n
        return best

    def _evict(self, node: _Node) -> None:
        assert not node.children and node.parent is not None
        # only payload-bearing nodes count as evictions: the recursive
        # cleanup of payload-less structural parents below drops no cached
        # boundary, so it must not inflate the metric past the evict_for/
        # trim return values
        if node.blocks is not None or node.state is not None:
            self.evictions += 1
        if node.blocks is not None:
            self._own(node.blocks, -1)
            self.backend.release(node.blocks)   # frees only at refcount 0
            node.blocks = None
        node.state = None
        node.parent.children.pop(node.edge[0])
        self.node_count -= 1
        parent = node.parent
        # structural nodes left payload-less and childless are dead weight
        if (parent is not self._root and not parent.children
                and parent.blocks is None and parent.state is None):
            self._evict(parent)

    def _own(self, blocks: list[int], delta: int) -> None:
        for b in blocks:
            c = self._block_owners.get(b, 0) + delta
            assert c >= 0, b
            if c:
                self._block_owners[b] = c
            else:
                self._block_owners.pop(b, None)


def _common_len(a: tuple[int, ...], b: tuple[int, ...]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n
