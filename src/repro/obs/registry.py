"""Thread-safe metrics registry: counters, gauges, fixed-bucket histograms.

One :class:`MetricsRegistry` per engine.  Design constraints, in order:

* **Determinism** — a dump of the registry is a pure function of the
  observations it absorbed: no wall-clock stamps, no id()s, sorted keys
  everywhere.  Two virtual-clock engine runs over the same trace produce
  byte-identical ``dump_json()`` / ``prometheus_text()`` output (pinned
  in ``tests/test_obs.py``).
* **Cheap on the hot path** — one shared lock, plain dict lookups, and a
  linear bucket scan per histogram observation (bucket lists are ~15
  entries).  The recording-overhead bound the bench gates (<3% decode
  tok/s) budgets for a handful of these per engine tick.
* **Prometheus-compatible** — ``prometheus_text()`` emits the text
  exposition format (``# HELP`` / ``# TYPE`` + samples; histograms as
  cumulative ``_bucket{le=...}`` series with ``_sum``/``_count``) so a
  stock Prometheus scraper can poll the ``/metrics`` endpoint that
  :func:`repro.obs.exporters.start_metrics_server` serves.

Counters here allow ``set()`` as well as ``add()``: the engine's bench
harness resets phase counters mid-run, and ``EngineMetrics`` (a live
view over this registry) keeps its historical read/write field contract.
"""
from __future__ import annotations

import json
import math
import threading

#: fixed histogram bucket grids (seconds).  Fixed — not adaptive — so two
#: runs of the same workload land observations in the same buckets and
#: dumps stay byte-comparable across runs and machines.
TTFT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                1.0, 2.5, 5.0, 10.0, 30.0)
ITL_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
               0.5, 1.0)
PHASE_BUCKETS = (1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3,
                 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.5, 1.0)
#: token-count grids for the speculative-decoding histograms: accepted
#: drafts per verify window (bounded by spec_k) and accepted/rejected
#: totals per retired request
SPEC_WINDOW_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0)
SPEC_REQUEST_BUCKETS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


def _label_key(labels: dict) -> tuple:
    """Canonical (sorted) label tuple — the per-series dict key."""
    return tuple(sorted(labels.items()))


def _fmt_value(v) -> str:
    """Prometheus sample value: integers without a trailing ``.0`` (they
    compare cleanly in dumps), floats via ``repr`` (round-trip exact)."""
    if isinstance(v, bool):
        return str(int(v))
    if isinstance(v, int) or (isinstance(v, float) and v.is_integer()
                              and abs(v) < 1e15):
        return str(int(v))
    return repr(float(v))


def _fmt_le(b: float) -> str:
    return "+Inf" if math.isinf(b) else _fmt_value(b)


class _Metric:
    """Shared plumbing: name, help text, label schema, per-series store."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: tuple[str, ...],
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._series: dict[tuple, object] = {}

    def _check(self, labels: dict) -> tuple:
        if tuple(sorted(labels)) != tuple(sorted(self.labelnames)):
            raise ValueError(
                f"{self.name}: labels {sorted(labels)} != declared "
                f"{sorted(self.labelnames)}")
        return _label_key(labels)

    def _series_items(self):
        """[(label_key, value)] sorted by label key — deterministic."""
        return sorted(self._series.items())


class Counter(_Metric):
    """Monotonic-by-convention numeric series (``add``); ``set`` exists
    for the EngineMetrics view's legacy reset contract."""

    kind = "counter"

    def add(self, value=1, **labels) -> None:
        key = self._check(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + value

    def set(self, value, **labels) -> None:
        key = self._check(labels)
        with self._lock:
            self._series[key] = value

    def value(self, **labels):
        with self._lock:
            return self._series.get(_label_key(labels), 0)


class Gauge(_Metric):
    """A point-in-time level (queue depth, pool occupancy)."""

    kind = "gauge"

    def set(self, value, **labels) -> None:
        key = self._check(labels)
        with self._lock:
            self._series[key] = value

    def add(self, value=1, **labels) -> None:
        key = self._check(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + value

    def value(self, **labels):
        with self._lock:
            return self._series.get(_label_key(labels), 0)


class Histogram(_Metric):
    """Fixed-bucket histogram: per-series cumulative counts + sum.

    Buckets are upper bounds (``le``); an implicit ``+Inf`` bucket always
    exists.  Percentile-free by design — the bench keeps exact latency
    percentiles, the registry keeps scrape-friendly distributions.
    """

    kind = "histogram"

    def __init__(self, name, help, labelnames, lock, buckets):
        super().__init__(name, help, labelnames, lock)
        b = tuple(float(x) for x in buckets)
        if not b or sorted(b) != list(b):
            raise ValueError(f"{name}: buckets must be sorted, got {b}")
        self.buckets = b

    def observe(self, value: float, **labels) -> None:
        key = self._check(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._series[key] = {
                    "counts": [0] * (len(self.buckets) + 1), "sum": 0.0}
            i = 0
            for i, b in enumerate(self.buckets):
                if value <= b:
                    break
            else:
                i = len(self.buckets)
            s["counts"][i] += 1
            s["sum"] += float(value)

    def count(self, **labels) -> int:
        with self._lock:
            s = self._series.get(_label_key(labels))
            return sum(s["counts"]) if s else 0


class MetricsRegistry:
    """Namespace of metrics sharing one lock.

    >>> reg = MetricsRegistry()
    >>> c = reg.counter("requests_total", "requests seen", ("priority",))
    >>> c.add(priority="0"); c.add(2, priority="1")
    >>> c.value(priority="1")
    2
    >>> print(reg.prometheus_text().strip())
    # HELP requests_total requests seen
    # TYPE requests_total counter
    requests_total{priority="0"} 1
    requests_total{priority="1"} 2
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _register(self, cls, name, help, labelnames, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if type(m) is not cls or m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-registered with a different "
                        "type or label schema")
                return m
            m = cls(name, help, tuple(labelnames), self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter:
        return self._register(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._register(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets=PHASE_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, labelnames,
                              buckets=buckets)

    # --- export ---------------------------------------------------------
    def dump(self) -> dict:
        """JSON-able snapshot: {metric: {kind, help, series}} with label
        keys flattened to ``k="v",...`` strings.  Deterministic (sorted)
        — two identical runs produce identical dumps."""
        out = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            series = {}
            for key, val in m._series_items():
                lk = ",".join(f'{k}="{v}"' for k, v in key)
                if isinstance(m, Histogram):
                    series[lk] = {
                        "buckets": {_fmt_le(b): c for b, c in
                                    zip((*m.buckets, math.inf),
                                        _cum(val["counts"]))},
                        "sum": val["sum"],
                        "count": sum(val["counts"]),
                    }
                else:
                    series[lk] = val
            out[name] = {"kind": m.kind, "help": m.help, "series": series}
        return out

    def dump_json(self) -> str:
        return json.dumps(self.dump(), indent=2, sort_keys=True)

    def prometheus_text(self) -> str:
        """Prometheus text exposition (version 0.0.4)."""
        lines = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, val in m._series_items():
                base = ",".join(f'{k}="{v}"' for k, v in key)
                if isinstance(m, Histogram):
                    for b, c in zip((*m.buckets, math.inf),
                                    _cum(val["counts"])):
                        le = f'le="{_fmt_le(b)}"'
                        lbl = f"{base},{le}" if base else le
                        lines.append(f"{name}_bucket{{{lbl}}} {c}")
                    sfx = f"{{{base}}}" if base else ""
                    lines.append(f"{name}_sum{sfx} {_fmt_value(val['sum'])}")
                    lines.append(f"{name}_count{sfx} "
                                 f"{sum(val['counts'])}")
                else:
                    sfx = f"{{{base}}}" if base else ""
                    lines.append(f"{name}{sfx} {_fmt_value(val)}")
        return "\n".join(lines) + "\n"


def _cum(counts: list[int]):
    """Cumulative bucket counts (Prometheus ``le`` semantics)."""
    total = 0
    for c in counts:
        total += c
        yield total
