"""Observability subsystem: metrics registry, request tracer, exporters.

Three pieces, all stdlib-only and clock-agnostic:

* :mod:`repro.obs.registry` — a thread-safe :class:`MetricsRegistry` of
  counters, gauges, and fixed-bucket histograms with label support; the
  engine's ``EngineMetrics`` is a live view over it, and it exports as
  Prometheus text exposition or a deterministic JSON dump.
* :mod:`repro.obs.trace` — a ring-buffered request-lifecycle
  :class:`Tracer` stamping every span event from an injected clock (the
  engine's single time base), exportable as Perfetto/Chrome
  ``trace_event`` JSON.
* :mod:`repro.obs.exporters` — an optional background HTTP thread
  serving ``/metrics`` (Prometheus scrape endpoint) plus file-dump
  helpers for both exposition formats.

See ``docs/observability.md`` for metric names, the event schema, and
the recording-overhead bound.
"""
from repro.obs.exporters import (dump_metrics, dump_trace,
                                 start_metrics_server)
from repro.obs.registry import (ITL_BUCKETS, PHASE_BUCKETS,
                                SPEC_REQUEST_BUCKETS, SPEC_WINDOW_BUCKETS,
                                TTFT_BUCKETS, Counter, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.trace import TraceEvent, Tracer, perfetto_json

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TTFT_BUCKETS", "ITL_BUCKETS", "PHASE_BUCKETS",
    "SPEC_WINDOW_BUCKETS", "SPEC_REQUEST_BUCKETS",
    "TraceEvent", "Tracer", "perfetto_json",
    "start_metrics_server", "dump_metrics", "dump_trace",
]
