"""Request-lifecycle tracer: ring-buffered span events, Perfetto export.

The engine emits one structured event per lifecycle transition —
``submit -> queue -> admit -> prefill_chunk* -> first_token -> token* ->
preempt/cancel -> finish`` — plus complete-span events for the per-tick
engine phases (``admit``/``prefill``/``decode``/``emit``).  Every stamp
comes from the clock injected at construction (the engine's single time
base), which is what makes virtual-clock load-harness traces
byte-identical across repeated runs: no wall time ever leaks into an
event.

Recording is OFF by default (``enabled=False`` → :meth:`Tracer.event` is
a cheap early-return) and ring-buffered when on: a bounded
``collections.deque`` drops the oldest events under overflow and counts
the drops (``dropped``), so a long-running serve loop can trace forever
in fixed memory and the export is honest about truncation.

Export is the Chrome/Perfetto ``trace_event`` JSON format (open the file
at https://ui.perfetto.dev or ``chrome://tracing``): one track per
request (pid 1, tid = rid) carrying instant lifecycle events, one track
per engine phase lane (pid 0) carrying complete ``X`` spans.  The JSON
is rendered with sorted keys and stable separators — byte-identical for
identical event sequences (pinned in ``tests/test_obs.py``).
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from dataclasses import dataclass, field

#: lifecycle event names a request track can carry, in canonical order
REQUEST_EVENTS = ("submit", "queue", "admit", "prefill_chunk",
                  "first_token", "token", "preempt", "cancel", "finish")

#: engine-track phase names (complete spans, one lane each)
PHASE_EVENTS = ("admit", "prefill", "decode", "draft", "verify", "emit")

_ENGINE_PID = 0
_REQUEST_PID = 1


@dataclass(frozen=True)
class TraceEvent:
    """One trace record.  ``rid`` is None for engine-phase spans;
    ``dur`` is None for instant events.  ``ts``/``dur`` are clock
    seconds (the export converts to microseconds)."""
    name: str
    ts: float
    rid: int | None = None
    dur: float | None = None
    args: dict = field(default_factory=dict)


class Tracer:
    """Bounded, clock-stamped event recorder.

    >>> clk = iter([0.0, 0.5, 0.75]).__next__
    >>> tr = Tracer(clock=clk, capacity=8, enabled=True)
    >>> tr.event("submit", rid=3, priority=1)
    >>> with tr.span("decode"):
    ...     pass
    >>> [(e.name, e.ts, e.rid) for e in tr.events()]
    [('submit', 0.0, 3), ('decode', 0.5, None)]
    >>> tr.events()[1].dur
    0.25
    """

    def __init__(self, clock=None, capacity: int = 65536,
                 enabled: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.clock = clock if clock is not None else time.perf_counter
        self.capacity = capacity
        self.enabled = enabled
        self._buf: deque[TraceEvent] = deque(maxlen=capacity)
        self._seen = 0            # lifetime appends (dropped = seen - len)
        self._lock = threading.Lock()

    @property
    def dropped(self) -> int:
        """Events lost to ring-buffer overflow."""
        with self._lock:
            return self._seen - len(self._buf)

    def event(self, name: str, *, rid: int | None = None, ts: float | None
              = None, dur: float | None = None, **args) -> None:
        """Record one event (no-op while disabled).  ``ts`` defaults to
        the injected clock's now; pass it explicitly to stamp a span you
        timed yourself (the engine reuses its metric timestamps so trace
        and registry never disagree)."""
        if not self.enabled:
            return
        e = TraceEvent(name, self.clock() if ts is None else ts,
                       rid=rid, dur=dur, args=args)
        with self._lock:
            self._buf.append(e)
            self._seen += 1

    def span(self, name: str, *, rid: int | None = None, **args):
        """Context manager recording ``name`` as a complete span over the
        enclosed block (clock-stamped entry/exit)."""
        return _Span(self, name, rid, args)

    def events(self) -> list[TraceEvent]:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._seen = 0

    def perfetto(self) -> str:
        """The ring buffer as Chrome ``trace_event`` JSON text."""
        return perfetto_json(self.events())


class _Span:
    __slots__ = ("_tr", "_name", "_rid", "_args", "_t0")

    def __init__(self, tracer, name, rid, args):
        self._tr, self._name, self._rid, self._args = tracer, name, rid, args

    def __enter__(self):
        self._t0 = self._tr.clock() if self._tr.enabled else 0.0
        return self

    def __exit__(self, *exc):
        if self._tr.enabled:
            t1 = self._tr.clock()
            self._tr.event(self._name, rid=self._rid, ts=self._t0,
                           dur=t1 - self._t0, **self._args)
        return False


def request_events(events: list[TraceEvent]) -> dict[int, list[TraceEvent]]:
    """Group the request-track events by rid, preserving order."""
    out: dict[int, list[TraceEvent]] = {}
    for e in events:
        if e.rid is not None:
            out.setdefault(e.rid, []).append(e)
    return out


def perfetto_json(events: list[TraceEvent]) -> str:
    """Render events as Chrome/Perfetto ``trace_event`` JSON.

    Deterministic: sorted JSON keys, compact separators, metadata rows
    emitted in sorted track order — identical event lists produce
    byte-identical text.
    """
    rows = []
    rids = sorted({e.rid for e in events if e.rid is not None})
    rows.append({"ph": "M", "pid": _ENGINE_PID, "tid": 0,
                 "name": "process_name", "args": {"name": "engine"}})
    phase_tids = {p: i for i, p in enumerate(PHASE_EVENTS)}
    for p, tid in phase_tids.items():
        rows.append({"ph": "M", "pid": _ENGINE_PID, "tid": tid,
                     "name": "thread_name", "args": {"name": f"phase:{p}"}})
    rows.append({"ph": "M", "pid": _REQUEST_PID, "tid": 0,
                 "name": "process_name", "args": {"name": "requests"}})
    for rid in rids:
        rows.append({"ph": "M", "pid": _REQUEST_PID, "tid": rid,
                     "name": "thread_name",
                     "args": {"name": f"request {rid}"}})
    for e in events:
        us = e.ts * 1e6
        if e.rid is None:
            row = {"name": e.name, "pid": _ENGINE_PID,
                   "tid": phase_tids.get(e.name, len(PHASE_EVENTS)),
                   "ts": us}
            if e.dur is not None:
                row.update(ph="X", dur=e.dur * 1e6)
            else:
                row.update(ph="i", s="p")
        else:
            row = {"name": e.name, "pid": _REQUEST_PID, "tid": e.rid,
                   "ts": us}
            if e.dur is not None:
                row.update(ph="X", dur=e.dur * 1e6)
            else:
                row.update(ph="i", s="t")
        if e.args:
            row["args"] = e.args
        rows.append(row)
    return json.dumps({"displayTimeUnit": "ms", "traceEvents": rows},
                      sort_keys=True, separators=(",", ":"))
