"""Exporters: Prometheus scrape endpoint + file dumps (stdlib only).

:func:`start_metrics_server` runs a ``ThreadingHTTPServer`` on a daemon
thread serving the registry's text exposition at ``/metrics`` (and its
JSON dump at ``/metrics.json``) — wire it to ``--metrics-port``.  The
registry is read under its own lock per scrape, so the serve loop never
blocks on an exporter.

:func:`dump_metrics` / :func:`dump_trace` write the one-shot file forms
(``--metrics-dump`` / ``--trace-out``): Prometheus text and
Perfetto/Chrome ``trace_event`` JSON respectively.
"""
from __future__ import annotations

import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer


def start_metrics_server(registry, port: int, host: str = "127.0.0.1"):
    """Serve ``registry`` at ``http://host:port/metrics`` from a daemon
    thread.  Returns the server; call ``.shutdown()`` to stop it.  The
    bound port is ``server.server_address[1]`` (pass ``port=0`` to let
    the OS pick — handy in tests)."""

    class Handler(BaseHTTPRequestHandler):
        def do_GET(self):
            if self.path.split("?")[0] == "/metrics":
                body = registry.prometheus_text().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            elif self.path.split("?")[0] == "/metrics.json":
                body = registry.dump_json().encode()
                ctype = "application/json"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):   # keep the serve loop's stdout clean
            pass

    server = ThreadingHTTPServer((host, port), Handler)
    server.daemon_threads = True
    t = threading.Thread(target=server.serve_forever,
                         name="metrics-exporter", daemon=True)
    t.start()
    return server


def dump_metrics(registry, path: str) -> str:
    """Write the registry's Prometheus text exposition to ``path``."""
    text = registry.prometheus_text()
    with open(path, "w") as f:
        f.write(text)
    return text


def dump_trace(tracer, path: str) -> str:
    """Write the tracer's ring buffer as Perfetto JSON to ``path``."""
    text = tracer.perfetto()
    with open(path, "w") as f:
        f.write(text)
    return text
