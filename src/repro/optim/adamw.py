"""AdamW with ZeRO-1-style sharded states, global-norm clipping, schedules.

Under pjit, ZeRO-1 falls out of sharding: m/v carry the same PartitionSpecs
as their params (which are already FSDP-sharded over ``data``), so optimizer
state is never replicated.  fp32 master moments regardless of param dtype.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


class AdamW:
    def __init__(self, lr=3e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
                 clip_norm: float | None = 1.0, schedule=None):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        self.weight_decay = weight_decay
        self.clip_norm = clip_norm
        self.schedule = schedule       # callable step -> multiplier

    def init(self, params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), zeros,
                          jax.tree.map(jnp.copy, zeros))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)

        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) *
                         g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self.lr * (self.schedule(step) if self.schedule else 1.0)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), {"grad_norm": gnorm,
                                                    "lr": lr}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn
