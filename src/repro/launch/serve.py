"""Serving CLI: batched requests against any assigned arch (reduced or full).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --quant luna_approx --requests 8 --sampling top_k --top-k 40
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--quant", default="bf16")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prefill-bucket", type=int, default=16,
                    help="prompt lengths are padded up to multiples of this "
                         "and prefilled one jit call per bucket")
    ap.add_argument("--paged", action="store_true",
                    help="paged-block KV cache: per-request block "
                         "reservation instead of full max-seq rows "
                         "(attention families)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="tokens per KV block in --paged mode")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="pool size in blocks (default: dense-equivalent "
                         "capacity + the reserved garbage block)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="admit prompts longer than this in N-token chunks "
                         "interleaved with decode ticks")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix-tree prompt-prefix sharing: warm "
                         "admissions reuse cached KV blocks (attention, "
                         "needs --paged) or recurrent state snapshots "
                         "(ssm) and prefill only the uncached tail")
    ap.add_argument("--prefix-cache-nodes", type=int, default=256,
                    help="LRU budget for cached prefix boundaries")
    ap.add_argument("--sampling", default="greedy",
                    choices=["greedy", "temperature", "top_k"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.core.layers import QuantConfig
    from repro.models.registry import get_config, get_model
    from repro.serve.engine import Engine, Request
    from repro.serve.sampling import SamplingConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant != "bf16":
        from dataclasses import replace
        cfg = replace(cfg, quant=QuantConfig(mode=args.quant))

    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sampling = SamplingConfig(mode=args.sampling,
                              temperature=args.temperature,
                              top_k=args.top_k)
    engine = Engine(cfg, params, max_batch=args.max_batch,
                    max_seq=args.max_seq, sampling=sampling,
                    seed=args.seed, prefill_bucket=args.prefill_bucket,
                    paged=args.paged, block_size=args.block_size,
                    num_blocks=args.num_blocks,
                    prefill_chunk=args.prefill_chunk,
                    prefix_cache=args.prefix_cache,
                    prefix_cache_nodes=args.prefix_cache_nodes)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    stats = engine.serve(reqs)
    tok_count = sum(len(r.out) for r in reqs)
    print(f"{tok_count} tokens over {len(reqs)} requests: "
          f"{stats['wall_s']:.2f}s wall, done={stats['done']}")
    print(f"  prefill: {stats['prefill_tokens']} tok in "
          f"{stats['prefill_s']:.2f}s ({stats['prefill_tok_s']:.0f} tok/s, "
          f"{stats['prefill_calls']} bucket calls)")
    print(f"  decode:  {stats['decode_tokens']} tok in "
          f"{stats['decode_s']:.2f}s ({stats['decode_tok_s']:.0f} tok/s, "
          f"occupancy {stats['occupancy']:.0%})")
    if args.prefix_cache:
        print(f"  prefix:  {stats['prefix_hits']} hits, "
              f"{stats['prefix_tokens_reused']} tok reused, "
              f"{stats['cache_evictions']} evictions")


if __name__ == "__main__":
    main()
