"""Serving CLI: batched requests against any assigned arch (reduced or full).

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --quant luna_approx --requests 8 --sampling top_k --top-k 40

  # LUT-quantized decode hot path (engine-level, D&C sub-table gemm):
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --quant lut4

  # non-affine NF4 decode (D&C + residual correction; nf4p = pruned):
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --quant nf4

  # speculative decoding (greedy-only; see docs/speculative.md):
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --spec ngram
  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b \
      --spec self_lut --spec-k 4     # nf4p LUT drafts, full-prec verify

Engine knobs are single-sourced in ``repro.serve.config.EngineConfig`` —
``EngineConfig.add_cli_args`` registers the flags (including the shared
``--quant``), ``from_args`` builds the validated config.  ``--quant
lut4|int4|nf4|nf4p`` freezes 4-bit decode weights on the engine (affine
grid or NF4 codebook with full/pruned residual correction — see
docs/quantization.md); any other spelling (bf16, int8, luna_*, ...) is a
model-level mode applied to every projection dynamically.

The CLI serves from the BACKGROUND LOOP by default (``engine.start()``,
one ``submit()`` per request, streams consumed off the loop thread,
``engine.stop()`` drains) — the same path a network front-end would use.
``--sync`` keeps the old caller-pumped ``engine.serve(requests)`` path.

Observability (see docs/observability.md): ``--metrics-port`` serves the
engine's metrics registry as a Prometheus scrape endpoint while the run
lasts, ``--metrics-dump PATH`` writes the text exposition on exit, and
``--trace-out PATH`` records request-lifecycle spans and writes Perfetto
JSON on exit (open at https://ui.perfetto.dev).
"""
from __future__ import annotations

import argparse


def main():
    from repro.serve.config import ENGINE_QUANT_MODES, EngineConfig

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--sync", action="store_true",
                    help="caller-pumped engine.serve() instead of the "
                         "background serve loop")
    EngineConfig.add_cli_args(ap)
    ap.set_defaults(max_batch=4, max_seq=128, quant="bf16")
    args = ap.parse_args()

    from dataclasses import replace

    import jax
    import numpy as np

    from repro.core.layers import QuantConfig
    from repro.models.registry import get_config, get_model
    from repro.serve.engine import Engine, Request

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant not in ("bf16", *ENGINE_QUANT_MODES):
        cfg = replace(cfg, quant=QuantConfig(mode=args.quant))

    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(cfg, params, EngineConfig.from_args(args))
    metrics_server = None
    if args.metrics_port is not None:
        from repro.obs import start_metrics_server
        metrics_server = start_metrics_server(engine.registry,
                                              args.metrics_port)
        print(f"metrics: http://127.0.0.1:"
              f"{metrics_server.server_address[1]}/metrics")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                    max_new=args.max_new)
            for i in range(args.requests)]
    if args.sync:
        stats = engine.serve(reqs)
    else:
        from concurrent.futures import ThreadPoolExecutor

        start = engine.metrics.snapshot()
        t0 = engine.clock()
        engine.start()
        handles = [engine.submit(r) for r in reqs]
        with ThreadPoolExecutor(max_workers=min(8, len(handles))) as pool:
            streams = list(pool.map(lambda h: list(h.tokens()), handles))
        engine.stop()
        for r, s in zip(reqs, streams):
            assert s == r.out, f"rid {r.rid}: stream diverged from out"
        stats = engine.metrics.since(start).summary(engine.max_batch)
        stats.update({"wall_s": engine.clock() - t0,
                      "done": all(r.done for r in reqs)})
    tok_count = sum(len(r.out) for r in reqs)
    print(f"{tok_count} tokens over {len(reqs)} requests: "
          f"{stats['wall_s']:.2f}s wall, done={stats['done']}")
    print(f"  prefill: {stats['prefill_tokens']} tok in "
          f"{stats['prefill_s']:.2f}s ({stats['prefill_tok_s']:.0f} tok/s, "
          f"{stats['prefill_calls']} bucket calls)")
    print(f"  decode:  {stats['decode_tokens']} tok in "
          f"{stats['decode_s']:.2f}s ({stats['decode_tok_s']:.0f} tok/s, "
          f"occupancy {stats['occupancy']:.0%})")
    if args.prefix_cache:
        print(f"  prefix:  {stats['prefix_hits']} hits, "
              f"{stats['prefix_tokens_reused']} tok reused, "
              f"{stats['cache_evictions']} evictions")
    # the rest of the summary: lifecycle + deadline accounting (zeros on
    # an ordinary run, but dropping them silently hid every non-zero one)
    print(f"  lifecycle: {stats['cancelled']} cancelled, "
          f"{stats['preemptions']} preempted")
    print(f"  deadlines: {stats['deadline_hits']} hit, "
          f"{stats['deadline_misses']} missed")
    if metrics_server is not None:
        metrics_server.shutdown()
    if args.metrics_dump:
        from repro.obs import dump_metrics
        dump_metrics(engine.registry, args.metrics_dump)
        print(f"metrics dump: {args.metrics_dump}")
    if args.trace_out:
        from repro.obs import dump_trace
        dump_trace(engine.tracer, args.trace_out)
        print(f"trace: {args.trace_out} "
              f"({len(engine.tracer.events())} events, "
              f"{engine.tracer.dropped} dropped)")


if __name__ == "__main__":
    main()
