"""Production training CLI.

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 100 \
      --reduced --quant luna_approx

``--reduced`` runs the smoke-scale config (CPU-friendly); without it the
full assigned config is used (real accelerators).  The mesh defaults to all
local devices; on a pod slice, start one process per host and the same code
path scales (jax.distributed initialization hook included).
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--quant", default="bf16")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host platform devices (CPU testing)")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")
    import jax
    if args.distributed:
        jax.distributed.initialize()

    from repro.core.layers import QuantConfig
    from repro.data.synthetic import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.registry import get_config
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant != "bf16":
        from dataclasses import replace
        cfg = replace(cfg, quant=QuantConfig(mode=args.quant))

    mesh = make_host_mesh(model=args.model_parallel)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                         microbatch=args.microbatch,
                         grad_compression=args.grad_compression)
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch)
    trainer = Trainer(cfg, tcfg, mesh)
    trainer.run(data)


if __name__ == "__main__":
    main()
