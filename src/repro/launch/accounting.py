"""Exact cost accounting via layer-count probes.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of trip
count, so a scanned 48-layer model under-reports FLOPs/bytes/collectives by
~48x.  Fix: lower small UNROLLED probe configs (python-loop layers, unrolled
attention/xent/SSD chunk loops — no while loops anywhere), measure each, and
solve the linear system

    metric(probe_i) = sum_c counts_i[c] * cost[c]

for the per-component costs, then extrapolate to the full layer stack.
Unrolled 1-2 layer probes compile in seconds; the REAL (scanned) lowering is
still what proves sharding/memory — probes only fix the arithmetic.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import EncDecConfig

METRICS = ("hlo_flops", "hlo_bytes", "collective_bytes",
           "coll_all_gather", "coll_all_reduce", "coll_reduce_scatter",
           "coll_all_to_all", "coll_collective_permute")


def probe_plan(cfg, kind: str):
    """Returns (probes, full_counts): probes = [(cfg_overrides, counts)]."""
    fam = cfg.family
    L = cfg.num_layers
    base_over = {"scan_layers": False}
    if fam in ("dense", "ssm", "vlm"):
        probes = [({"num_layers": 1}, {"base": 1, "layer": 1}),
                  ({"num_layers": 2}, {"base": 1, "layer": 2})]
        full = {"base": 1, "layer": L}
    elif fam == "moe":
        nd = cfg.moe.first_dense
        probes = [({"num_layers": nd + 1}, {"base": 1, "moe": 1}),
                  ({"num_layers": nd + 2}, {"base": 1, "moe": 2})]
        full = {"base": 1, "moe": L - nd}
    elif fam == "hybrid":
        per = cfg.hybrid.period
        # L=1/L=per isolate the mamba marginal; L=per+1 adds a 2nd shared-
        # attention application.  Max unrolled depth = per+1 (compile cost).
        probes = [
            ({"num_layers": 1}, {"base": 1, "attn": 1, "mamba": 1}),
            ({"num_layers": per}, {"base": 1, "attn": 1, "mamba": per}),
            ({"num_layers": per + 1}, {"base": 1, "attn": 2,
                                       "mamba": per + 1}),
        ]
        n_groups = (L + per - 1) // per
        full = {"base": 1, "attn": n_groups, "mamba": L}
    elif fam == "encdec":
        es = cfg.encdec.enc_seq
        if kind == "decode":
            probes = [({"num_layers": 1}, {"base": 1, "dec": 1}),
                      ({"num_layers": 2}, {"base": 1, "dec": 2})]
            full = {"base": 1, "dec": L}
        else:
            probes = [
                ({"num_layers": 1,
                  "encdec": EncDecConfig(1, es)}, {"base": 1, "enc": 1,
                                                   "dec": 1}),
                ({"num_layers": 1,
                  "encdec": EncDecConfig(2, es)}, {"base": 1, "enc": 2,
                                                   "dec": 1}),
                ({"num_layers": 2,
                  "encdec": EncDecConfig(1, es)}, {"base": 1, "enc": 1,
                                                   "dec": 2}),
            ]
            full = {"base": 1, "enc": cfg.encdec.enc_layers, "dec": L}
    else:
        raise ValueError(fam)
    probes = [({**base_over, **o}, c) for o, c in probes]
    return probes, full


def _metrics_of(rec: dict) -> np.ndarray:
    bd = rec["collective_breakdown"]
    return np.array([
        rec["hlo_flops"], rec["hlo_bytes"], rec["collective_bytes"],
        bd["all-gather"], bd["all-reduce"], bd["reduce-scatter"],
        bd["all-to-all"], bd["collective-permute"],
    ])


def extrapolate(probe_recs: list[dict], probes, full_counts) -> dict:
    comps = sorted({c for _, counts in probes for c in counts})
    A = np.array([[counts.get(c, 0) for c in comps] for _, counts in probes],
                 dtype=np.float64)
    F = np.stack([_metrics_of(r) for r in probe_recs])       # (P, M)
    X, *_ = np.linalg.lstsq(A, F, rcond=None)                # (C, M)
    fvec = np.array([full_counts.get(c, 0) for c in comps], np.float64)
    total = fvec @ X                                         # (M,)
    total = np.maximum(total, 0.0)
    out = dict(zip(METRICS, total.tolist()))
    out["probe_residual"] = float(np.abs(A @ X - F).max() /
                                  (np.abs(F).max() + 1e-9))
    return out
