import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each supported cell this script:
  1. builds the production mesh (16x16 single pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for params / optimizer / batch /
     caches with full NamedShardings,
  3. ``jax.jit(step, in_shardings=...).lower(...).compile()``,
  4. records memory_analysis() / cost_analysis() / collective bytes
     into results/dryrun/<cell>.json (read later by the roofline report).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all            # every cell, both meshes
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ALL_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (active_params, collective_bytes,
                                   count_params, model_flops, roofline_terms)
from repro.models.registry import (ARCH_IDS, cell_supported, get_config,
                                   get_model, input_specs)
from repro.optim.adamw import AdamW
from repro.parallel import sharding as shd
from repro.parallel.act_sharding import activation_sharding
from repro.train.train_step import make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _sds_tree(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               quant: str = "bf16", extra_cfg: dict | None = None) -> dict:
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    ok, why = cell_supported(arch, shape)
    if not ok:
        return {"status": "skip", "reason": why}

    cfg = get_config(arch, **(extra_cfg or {}))
    if quant != "bf16":
        from repro.core.layers import QuantConfig
        from dataclasses import replace
        cfg = replace(cfg, quant=QuantConfig(mode=quant))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    model = get_model(cfg)
    t0 = time.time()

    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    serve_tp = (shape.kind != "train"
                and getattr(cfg, "serve_param_sharding", "fsdp") == "tp")
    p_sh = shd.param_shardings(params_shape, mesh, serve_tp=serve_tp)
    n_params = count_params(params_shape)

    if shape.kind == "train":
        opt = AdamW()
        step_fn, _ = make_train_step(cfg, opt, mesh)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        from repro.optim.adamw import AdamWState
        opt_sh = AdamWState(shd.scalar_sharding(mesh), p_sh, p_sh)
        batch_shape = input_specs(cfg, shape)
        b_sh = shd.batch_shardings(batch_shape, mesh)
        with mesh, activation_sharding(mesh):
            lowered = jax.jit(
                step_fn, in_shardings=(p_sh, opt_sh, b_sh),
                donate_argnums=(0, 1),
            ).lower(params_shape, opt_shape, batch_shape)
    elif shape.kind == "prefill":
        batch_shape = input_specs(cfg, shape)
        b_sh = shd.batch_shardings(batch_shape, mesh)
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_sh = shd.cache_shardings(cache_shape, mesh)

        def prefill_step(params, batch, caches):
            kwargs = {k: v for k, v in batch.items()
                      if k in ("frames", "patches")}
            toks = batch["tokens"]
            return model.prefill(params, toks, caches, **kwargs)

        with mesh, activation_sharding(mesh):
            lowered = jax.jit(
                prefill_step, in_shardings=(p_sh, b_sh, c_sh),
                donate_argnums=(2,),
            ).lower(params_shape, batch_shape, cache_shape)
    else:  # decode
        b = shape.global_batch
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(b, shape.seq_len))
        # enc-dec serve state = (caches, enc_out)
        if cfg.family == "encdec":
            enc_out = jax.ShapeDtypeStruct(
                (b, cfg.encdec.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))
            cache_shape = (cache_shape, enc_out)
        c_sh = shd.cache_shardings(cache_shape, mesh)
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tok_sh = shd.batch_shardings({"token": tok}, mesh)["token"]
        idx = jax.ShapeDtypeStruct((), jnp.int32)

        def decode_step(params, token, caches, index):
            return model.decode_step(params, token, caches, index)

        with mesh, activation_sharding(mesh):
            lowered = jax.jit(
                decode_step,
                in_shardings=(p_sh, tok_sh, c_sh, shd.scalar_sharding(mesh)),
                donate_argnums=(2,),
            ).lower(params_shape, tok, cache_shape, idx)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    mf = model_flops(cfg, shape, n_params, active_params(cfg, n_params))
    rec = {
        "status": "ok", "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "quant": quant,
        "n_params": n_params, "n_active_params": active_params(cfg, n_params),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops": flops, "hlo_bytes": bytes_acc,
        "collective_bytes": coll["total"],
        "collective_breakdown": {k: coll[k] for k in
                                 ("all-gather", "all-reduce",
                                  "reduce-scatter", "all-to-all",
                                  "collective-permute")},
        "collective_op_counts": coll["op_counts"],
        "model_flops": mf,
        "memory_analysis": {
            "bytes_per_device_argument": int(
                getattr(mem, "argument_size_in_bytes", 0)),
            "bytes_per_device_output": int(
                getattr(mem, "output_size_in_bytes", 0)),
            "bytes_per_device_temp": int(
                getattr(mem, "temp_size_in_bytes", 0)),
            "bytes_per_device_peak_estimate": int(
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)),
        },
    }
    return rec


def account_cell(arch: str, shape_name: str, multi_pod: bool,
                 quant: str = "bf16", extra_cfg: dict | None = None) -> dict:
    """Exact per-device totals via unrolled layer-count probes
    (see launch/accounting.py — fixes the while-loop undercount)."""
    from repro.launch.accounting import extrapolate, probe_plan
    shape = {s.name: s for s in ALL_SHAPES}[shape_name]
    cfg = get_config(arch, **(extra_cfg or {}))
    if (cfg.ssm is not None and shape.kind != "decode"
            and shape.seq_len > 8192):
        # unrolled SSD probes at 128+ chunks are prohibitively slow to
        # compile on this host: use the documented analytic-FLOPs fallback
        # (bytes/collectives stay scanned-raw lower bounds).
        from repro.launch.roofline import analytic_flops
        return {"status": "analytic",
                "hlo_flops": analytic_flops(cfg, shape)}
    probes, full = probe_plan(cfg, shape.kind)
    recs = []
    for over, _counts in probes:
        r = lower_cell(arch, shape_name, multi_pod, quant=quant,
                       extra_cfg={**(extra_cfg or {}), **over})
        if r["status"] != "ok":
            return {"status": "fail", "error": "probe failed: "
                    + r.get("error", "?")}
        recs.append(r)
    return {"status": "ok", **extrapolate(recs, probes, full)}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             quant: str = "bf16", extra_cfg: dict | None = None) -> dict:
    """Full record: real (scanned) lowering + probe-extrapolated roofline."""
    rec = lower_cell(arch, shape_name, multi_pod, quant=quant,
                     extra_cfg=extra_cfg)
    if rec["status"] != "ok":
        return rec
    acct = account_cell(arch, shape_name, multi_pod, quant=quant,
                        extra_cfg=extra_cfg)
    if acct["status"] == "analytic":
        rec["accounting"] = "analytic_flops+scanned_bytes"
        rec["scanned_raw"] = {k: rec[k] for k in
                              ("hlo_flops", "hlo_bytes", "collective_bytes")}
        # analytic flops are GLOBAL; convert to the per-device convention
        rec["hlo_flops"] = flops = acct["hlo_flops"] / rec["chips"]
        bytes_acc = rec["hlo_bytes"]
        coll = rec["collective_bytes"]
    elif acct["status"] != "ok":
        rec["accounting_error"] = acct["error"]
        flops, bytes_acc = rec["hlo_flops"], rec["hlo_bytes"]
        coll = rec["collective_bytes"]
    else:
        rec["scanned_raw"] = {k: rec[k] for k in
                              ("hlo_flops", "hlo_bytes", "collective_bytes")}
        rec["hlo_flops"] = flops = acct["hlo_flops"]
        rec["hlo_bytes"] = bytes_acc = acct["hlo_bytes"]
        rec["collective_bytes"] = coll = acct["collective_bytes"]
        rec["collective_breakdown"] = {
            "all-gather": acct["coll_all_gather"],
            "all-reduce": acct["coll_all_reduce"],
            "reduce-scatter": acct["coll_reduce_scatter"],
            "all-to-all": acct["coll_all_to_all"],
            "collective-permute": acct["coll_collective_permute"]}
        rec["probe_residual"] = acct["probe_residual"]
    # NOTE: cost_analysis is per-device (partitioned module); roofline terms
    # divide global work by chips, so scale per-device -> global first.
    chips = rec["chips"]
    terms = roofline_terms(flops * chips, bytes_acc * chips, coll * chips,
                           chips)
    rec.update(terms)
    rec["useful_flops_ratio"] = (rec["model_flops"] / (flops * chips)
                                 if flops else 0.0)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--quant", default="bf16")
    args = ap.parse_args()

    RESULTS.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = ([s.name for s in ALL_SHAPES] if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if (args.all or args.both_meshes) else [args.multipod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                if args.quant != "bf16":
                    tag += f"__{args.quant}"
                out_path = RESULTS / f"{tag}.json"
                if out_path.exists():
                    print(f"[cached] {tag}")
                    continue
                print(f"[lower ] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, quant=args.quant)
                except Exception as e:  # noqa: BLE001
                    rec = {"status": "fail", "error": str(e)[:2000],
                           "traceback": traceback.format_exc()[-4000:]}
                    failures += 1
                out_path.write_text(json.dumps(rec, indent=1))
                if rec["status"] == "ok":
                    print(f"   ok: compile={rec['compile_s']}s "
                          f"dominant={rec['dominant']} "
                          f"roofline={rec['roofline_fraction']:.3f} "
                          f"peak/dev="
                          f"{rec['memory_analysis']['bytes_per_device_peak_estimate']/2**30:.2f}GiB",
                          flush=True)
                elif rec["status"] == "skip":
                    print(f"   skip: {rec['reason']}")
                else:
                    print(f"   FAIL: {rec['error'][:300]}")
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
