"""Roofline term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (DESIGN.md section 7):

    compute    = HLO_FLOPs      / (chips x 197e12 FLOP/s)      [bf16 MXU]
    memory     = HLO_bytes      / (chips x 819e9  B/s)         [HBM]
    collective = collective_B   / (chips x 45e9   B/s)         [ICI]

``cost_analysis()`` supplies FLOPs/bytes; collective bytes are parsed from
the compiled HLO text by summing operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute op.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # bf16 per chip (TPU v5e)
HBM_BW = 819e9               # B/s per chip
ICI_BW = 45e9                # B/s effective per chip (assignment: ~50 GB/s/link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,512,128]{2,1,0}"  or "f32[]"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO.

    Returns {op_kind: bytes} plus "total".  Uses the op's result shape
    (per-participant payload) — the standard proxy for link traffic.
    """
    out: dict[str, float] = {k: 0 for k in _COLLECTIVES}
    n_ops: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match:  %name = <shape> <op-kind>(...)
        m = re.match(r"%?[\w.\-]+ = ([^=]*?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-start" in ls.split("(")[0] and kind not in ls.split("(")[0]:
            pass
        out[kind] += _shape_bytes(shape_str)
        n_ops[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["op_counts"] = n_ops
    return out


def roofline_terms(flops: float, bytes_accessed: float, coll_bytes: float,
                   chips: int) -> dict:
    compute = flops / (chips * PEAK_FLOPS)
    memory = bytes_accessed / (chips * HBM_BW)
    collective = coll_bytes / (chips * ICI_BW)
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    terms["dominant"] = dom
    terms["step_time_lb_s"] = bound
    terms["roofline_fraction"] = compute / bound if bound > 0 else 0.0
    return terms


def model_flops(cfg, shape, n_params: int, n_active: int | None = None) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N per decoded token."""
    n = n_active if n_active is not None else n_params
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analytic_flops(cfg, shape) -> float:
    """Closed-form HLO-FLOP estimate for SSD-family cells whose unrolled
    probes are prohibitively expensive to compile (zamba2/mamba2 at 32k+).

    Counts matmul FLOPs only (2*M*N*K), x4 for training (fwd + full-remat
    recompute + 2x fwd for bwd), matching the probe-measured ratio on the
    cells where both methods ran (train_4k: analytic/probe ~ 0.9-1.1).
    """
    t = shape.global_batch * shape.seq_len if shape.kind != "decode" \
        else shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    d = cfg.d_model
    sc = cfg.ssm
    f = 0.0
    if sc is not None:
        d_inner = sc.expand * d
        h = d_inner // sc.head_dim
        gn = sc.num_groups * sc.state_dim
        conv_ch = d_inner + 2 * gn
        in_dim = 2 * d_inner + 2 * gn + h
        per_tok = (2 * d * in_dim + 2 * conv_ch * sc.conv_dim
                   + 2 * d_inner * d)
        q = min(sc.chunk_size, s)
        # SSD per token: intra (CB^T: q*gn*2; y: q*h*... per-token share)
        ssd_per_tok = (2 * q * gn            # C B^T column
                       + 2 * q * h * sc.head_dim / max(h, 1) * h  # y_intra
                       + 4 * h * sc.head_dim * sc.state_dim)      # states+inter
        n_ssm = cfg.num_layers
        f += t * n_ssm * (per_tok + ssd_per_tok)
    if cfg.hybrid is not None:
        hc = cfg.hybrid
        hd = d // hc.shared_num_heads
        n_app = (cfg.num_layers + hc.period - 1) // hc.period
        qkvo = 2 * d * hd * (2 * hc.shared_num_heads
                             + 2 * hc.shared_num_kv_heads)
        mlp3 = 3 * 2 * d * hc.shared_d_ff
        scores = 4 * s * hc.shared_num_heads * hd  # 2 matmuls x S keys
        f += t * n_app * (qkvo + mlp3 + scores)
    f += 2.0 * t * d * cfg.vocab_size          # logits
    if shape.kind == "train":
        f *= 4.0                                # remat + backward
    return f


def count_params(params_shape) -> int:
    import jax
    import numpy as np
    return int(sum(np.prod(a.shape) for a in jax.tree.leaves(params_shape)))


def active_params(cfg, n_params: int) -> int:
    """MoE: subtract non-activated expert weight (top_k+shared of E)."""
    if cfg.moe is None:
        return n_params
    mc = cfg.moe
    # per-layer routed expert params
    per_expert = 3 * cfg.d_model * mc.d_expert
    n_moe_layers = cfg.num_layers - mc.first_dense
    routed_total = n_moe_layers * mc.num_experts * per_expert
    routed_active = n_moe_layers * mc.top_k * per_expert
    return n_params - routed_total + routed_active
