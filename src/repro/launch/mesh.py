"""Production mesh construction (assignment-mandated shape).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

BATCH_AXES = ("pod", "data")     # axes that shard the global batch


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """A tiny mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)
