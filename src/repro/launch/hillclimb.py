import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Perf hillclimb driver: run a named variant of a cell and record the
roofline delta vs baseline into results/perf/<cell>__<variant>.json.

Usage:
  python -m repro.launch.hillclimb --arch deepseek-67b --shape decode_32k \
      --variant sharded_decode
"""
import argparse
import json
from pathlib import Path

from repro.launch.dryrun import run_cell

RESULTS = Path(__file__).resolve().parents[3] / "results" / "perf"

# variant name -> cfg overrides
VARIANTS = {
    "baseline": {},
    # decode: flash-decode shard_map (kills cache-reshard collectives)
    "sharded_decode": {"decode_attn": "sharded"},
    # decode iteration 2: + TP-only param sharding (no FSDP weight
    # all-gathers per token)
    "sharded_decode+tp": {"decode_attn": "sharded",
                          "serve_param_sharding": "tp"},
    # decode iteration 3: + grouped-bf16 flash-decode operands
    "sharded_decode+tp+bf16": {"decode_attn": "sharded",
                               "serve_param_sharding": "tp",
                               "decode_attn_precision": "bf16_grouped"},
    # train/prefill: bf16 attention operands (halves attention HBM bytes)
    "bf16_attn": {"attn_f32": False},
    # remat policy: save matmul outputs instead of recomputing everything
    "save_dots": {"remat_policy": "dots"},
    # larger attention chunk (fewer chunk-loop iterations, bigger tiles)
    "chunk_1024": {"attn_chunk": 1024},
    "chunk_2048": {"attn_chunk": 2048},
    # combined winners
    "bf16_attn+save_dots": {"attn_f32": False, "remat_policy": "dots"},
    "bf16_attn+chunk_2048": {"attn_f32": False, "attn_chunk": 2048},
    # fused scale+mask (one where() vs mul + broadcast-bias add)
    "fused_mask": {"attn_fused_mask": True},
    # flash-kernel block skipping modeled in accounting (halves causal work)
    "causal_skip": {"attn_fused_mask": True, "attn_causal_skip": True},
    "causal_skip+save_dots": {"attn_fused_mask": True,
                              "attn_causal_skip": True,
                              "remat_policy": "dots"},
    "causal_skip+bf16": {"attn_fused_mask": True, "attn_causal_skip": True,
                         "attn_f32": False},
    "sharded_decode+bf16_attn": {"decode_attn": "sharded", "attn_f32": False},
}


def run_variant(arch: str, shape: str, variant: str, multi_pod: bool = False,
                quant: str = "bf16") -> dict:
    overrides = dict(VARIANTS[variant])
    rec = run_cell(arch, shape, multi_pod, quant=quant, extra_cfg=overrides)
    rec["variant"] = variant
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}__{shape}__{'mp' if multi_pod else 'sp'}__{variant}"
    if quant != "bf16":
        tag += f"__{quant}"
    (RESULTS / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--quant", default="bf16")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.variant, args.multipod,
                      args.quant)
    if rec["status"] != "ok":
        print("FAIL", rec.get("error", "")[:500])
        return 1
    print(json.dumps({k: rec[k] for k in
                      ("variant", "compute_s", "memory_s", "collective_s",
                       "dominant", "roofline_fraction")}, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
