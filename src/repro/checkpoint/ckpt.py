"""Async, atomic, elastic checkpointing (numpy .npz — no external deps).

  * async: a background thread serializes host copies while training
    continues (the device->host copy is the only synchronous part);
  * atomic: writes to ``step_N.tmp/`` then ``os.rename`` — a crash never
    leaves a half checkpoint visible, restart picks the latest complete one;
  * elastic: arrays are saved as full (unsharded) host arrays keyed by
    pytree path; ``restore`` re-sorts them onto ANY mesh/sharding, so a
    512-chip checkpoint restores onto 4 devices and vice versa.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ---------------- save ----------------
    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """Device->host copy now; disk write in the background."""
        self.wait()                       # one in-flight checkpoint max
        host = _flatten(jax.tree.map(
            lambda x: jax.device_get(x) if hasattr(x, "device") else x, tree))

        def _write():
            tmp = self.dir / f"step_{step}.tmp"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            np.savez(tmp / "arrays.npz", **host)
            (tmp / "meta.json").write_text(json.dumps({"step": step}))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)         # atomic publish
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ---------------- restore ----------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "meta.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, target: Any, shardings: Any | None = None
                ) -> Any:
        """Restore onto the CURRENT mesh (elastic: any device count)."""
        data = np.load(self.dir / f"step_{step}" / "arrays.npz")
        flat_paths = jax.tree_util.tree_flatten_with_path(target)
        leaves, treedef = jax.tree_util.tree_flatten(target)
        sh_leaves = (jax.tree_util.tree_leaves(shardings)
                     if shardings is not None else [None] * len(leaves))
        out = []
        for (path, leaf), sh in zip(flat_paths[0], sh_leaves):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            arr = data[key]
            if arr.dtype.kind == "V":   # npz stores bf16 etc. as raw void
                arr = arr.view(np.dtype(leaf.dtype))
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)
