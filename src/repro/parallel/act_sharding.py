"""Activation-sharding hints (Megatron-SP style), applied via context.

Models are mesh-agnostic; the step factories activate a context carrying the
mesh, and models call :func:`shard_hidden` / :func:`shard_heads` at layer
boundaries.  Outside the context the hints are no-ops (tests, examples).

  hidden (B, S, D): batch over (pod, data), sequence over model (SP) —
      cuts the remat-carry footprint by the model-axis size and lets XLA
      place the all-gather/reduce-scatter pair around attention/MLP.
  per-head (B, S, H, Dh): batch over (pod, data), heads over model (TP).

Every constraint is shape-guarded (axes that don't divide are dropped), so
decode steps (S=1) and batch-1 cells degrade gracefully.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

_CTX = threading.local()


@contextmanager
def activation_sharding(mesh, *, sequence_parallel: bool = True):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, sequence_parallel)
    try:
        yield
    finally:
        _CTX.state = prev


def _guarded(x, full_axes):
    mesh, _ = _CTX.state
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = []
    for dim, ax in zip(x.shape, full_axes):
        if ax is None:
            spec.append(None)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        axs = tuple(a for a in axs if a in sizes)
        prod = 1
        for a in axs:
            prod *= sizes[a]
        spec.append((axs if len(axs) > 1 else axs[0])
                    if axs and dim % prod == 0 and dim >= prod else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def current_mesh():
    """The mesh of the active activation-sharding context (None outside)."""
    state = getattr(_CTX, "state", None)
    return state[0] if state is not None else None


def shard_hidden(x):
    """(B, S, D) at block boundaries."""
    state = getattr(_CTX, "state", None)
    if state is None:
        return x
    mesh, sp = state
    ba = batch_axes(mesh)
    seq_ax = "model" if sp else None
    return _guarded(x, (ba, seq_ax, None))


def shard_heads(x):
    """(B, S, H, Dh) inside attention."""
    state = getattr(_CTX, "state", None)
    if state is None:
        return x
    mesh, _ = _CTX.state
    ba = batch_axes(mesh)
    return _guarded(x, (ba, None, "model", None))
