"""Pipeline parallelism over the ``pod`` axis (GPipe-style, shard_map).

Inter-pod ICI/DCN links are the slow tier of a multi-pod mesh; running the
layer stack as P pipeline stages (one per pod) turns the per-layer inter-pod
traffic of pure data parallelism into one boundary activation transfer per
microbatch, hidden behind microbatch compute.

Schedule: standard GPipe fill/drain — T = n_micro + n_stages - 1 ticks; at
each tick stage s computes microbatch (t - s) if in range, then the boundary
activation moves s -> s+1 via ``collective_permute``.  Implemented with
``shard_map`` over the pod axis so each pod holds only its stage's weights
(the stage dim of the stacked params is sharded over ``pod``).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn, stage_params, x_micro, *, mesh,
                   axis_name: str = "pod"):
    """Run microbatches through pipeline stages.

    stage_fn(params_one_stage, x) -> y   (same shape as x)
    stage_params: pytree with leading [n_stages] dim (sharded over pod)
    x_micro: (n_micro, mb, ...) microbatched input (replicated over pod)
    Returns (n_micro, mb, ...) outputs (replicated over pod).
    """
    n_stages = mesh.shape[axis_name]
    n_micro = x_micro.shape[0]
    n_ticks = n_micro + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_pod(params_stage, xs):
        # params_stage: [1, ...] slice for this pod; xs: full microbatches
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        sidx = jax.lax.axis_index(axis_name)
        mb_shape = xs.shape[1:]
        buf = jnp.zeros(mb_shape, xs.dtype)          # current activation
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            mb_idx = t - sidx                         # microbatch at stage
            active = (mb_idx >= 0) & (mb_idx < n_micro)
            # stage 0 ingests microbatch t from xs
            x_in = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, n_micro - 1), 0, keepdims=False)
            inp = jnp.where(sidx == 0, x_in, buf)
            y = stage_fn(params_stage, inp)
            y = jnp.where(active, y, buf)
            # last stage emits into outs at mb_idx
            emit = active & (sidx == n_stages - 1)
            outs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(mb_idx, 0, n_micro - 1), 0),
                lambda o: o, outs)
            # shift boundary activations one stage forward
            buf = jax.lax.ppermute(y, axis_name, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # only the last pod holds real outputs; share them
        outs = jax.lax.psum(
            jnp.where(sidx == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis_name)
        return outs

    other_axes = tuple(a for a in mesh.axis_names if a != axis_name)
    in_specs = (jax.tree.map(lambda _: P(axis_name), stage_params),
                P())
    return shard_map(
        per_pod, mesh=mesh, in_specs=in_specs, out_specs=P(),
        check_rep=False)(stage_params, x_micro)
