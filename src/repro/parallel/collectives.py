"""Distributed-optimization collectives.

``int8 all-reduce with error feedback``: the DP gradient all-reduce is the
dominant inter-pod traffic for data parallelism; quantizing the payload to
int8 cuts it 4x vs f32 (2x vs bf16).  Error feedback (Seide et al. 2014;
Karimireddy et al. 2019) accumulates the local quantization residual into
the next step's gradient so the compression bias vanishes over time.

Two entry points:
  * :func:`quantized_psum` — inside shard_map: quantize, int32-accumulate
    psum, dequantize (exact int semantics, 4x less link traffic);
  * :func:`compress_grads_int8` — pjit-level simulation of the same
    round-trip (quantize->dequantize) so the training-quality effect is
    testable without shard_map plumbing; the wire format is the shard_map
    path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def _q8(x: jax.Array):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def quantized_psum(x: jax.Array, axis_name: str):
    """int8-payload psum (inside shard_map).  Scales are psum'd in f32 (tiny);
    payload goes over the wire as int8 -> int32 accumulate."""
    q, scale = _q8(x.astype(jnp.float32))
    # max-scale across participants so dequant is consistent
    gscale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / gscale),
                 -127, 127).astype(jnp.int8)
    acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return acc.astype(jnp.float32) * gscale


class ErrorFeedback:
    """Residual accumulator for compressed gradients (host-side state)."""

    def __init__(self):
        self.residual = None

    def compress(self, grads):
        if self.residual is not None:
            grads = jax.tree.map(jnp.add, grads, self.residual)
        compressed = jax.tree.map(_roundtrip_q8, grads)
        self.residual = jax.tree.map(jnp.subtract, grads, compressed)
        return compressed


def _roundtrip_q8(x):
    x32 = x.astype(jnp.float32)
    q, scale = _q8(x32)
    return (q.astype(jnp.float32) * scale).astype(x.dtype)


def compress_grads_int8(grads):
    """Quantize-dequantize every gradient leaf (pjit-level; the all-reduce
    that follows then carries int8-precision payloads)."""
    return jax.tree.map(_roundtrip_q8, grads)
