"""Logical sharding rules: param/batch/cache PartitionSpecs per architecture.

Strategy (DESIGN.md section 6):
  * params: FSDP over ``data`` on the contraction-side dim + Megatron TP over
    ``model`` on heads / FFN-hidden / experts / vocab;
  * batch: sharded over ``(pod, data)``;
  * KV caches: heads over ``model`` when the KV-head count divides the axis,
    otherwise the sequence dim goes over ``model`` (ring-style cache);
  * every rule is shape-guarded: an axis is applied only if it divides the
    dim, so the same rules serve 512-chip pods and 2-device test meshes.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes

# (regex on 'a/b/c' param path) -> spec builder taking ndim
# Rules are matched in order; first hit wins.  Leading L (scan) axes are
# handled by padding the spec with None on the left.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                 ("model", "data")),     # (V, D) vocab-parallel
    (r"lm_head$",               ("data", "model")),     # (D, V)
    (r"router$",                ("data", None)),        # (D, E)
    # MoE experts: EP over model on the expert dim
    (r"moe/w_(gate|up)$",       ("model", "data", None)),   # (E, D, F)
    (r"moe/w_down$",            ("model", None, "data")),   # (E, F, D)
    (r"shared/w_(gate|up)$",    ("data", "model")),
    (r"shared/w_down$",         ("model", "data")),
    # MLA
    (r"w_dkv$",                 ("data", None)),
    (r"w_dq$",                  ("data", None)),
    (r"w_uq$",                  (None, "model")),
    (r"w_uk$",                  (None, "model")),
    (r"w_uv$",                  (None, "model")),
    # attention (GQA)
    (r"attn/w[qkv]$",           ("data", "model")),
    (r"attn/wo$",               ("model", "data")),
    # dense MLP
    (r"w_(gate|up)$",           ("data", "model")),
    (r"w_down$",                ("model", "data")),
    # mamba2 (inner dims stay unsharded over model; see DESIGN.md)
    (r"m/w_in$",                ("data", None)),
    (r"m/w_out$",               (None, "data")),
    (r"m/conv_[wb]$",           None),                  # replicated
    (r"(A_log|D|dt_bias|norm_w|ln\w*|ln_f|ln_enc|ln_dec)$", None),
]


def _guard(spec_axes, shape, mesh) -> P:
    """Drop axes that don't divide the corresponding dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, ax in zip(shape, spec_axes):
        if ax is None:
            out.append(None)
            continue
        axs = ax if isinstance(ax, tuple) else (ax,)
        axs = tuple(a for a in axs if a in sizes)
        prod = int(np.prod([sizes[a] for a in axs])) if axs else 1
        if axs and dim % prod == 0 and dim >= prod:
            out.append(axs if len(axs) > 1 else axs[0])
        else:
            out.append(None)
    return P(*out)


def param_spec(path: str, shape: tuple, mesh) -> P:
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            if axes is None:
                return P()
            axes = tuple(axes)
            # left-pad for stacked (scan) leading axes
            pad = len(shape) - len(axes)
            if pad < 0:   # unstacked smaller rank (e.g. per-layer bias)
                return P()
            full = (None,) * pad + axes
            return _guard(full, shape, mesh)
    return P()  # default: replicated


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_shardings(params_shape: Any, mesh, *, serve_tp: bool = False) -> Any:
    """Pytree of NamedShardings matching a params(-shape) pytree.

    ``serve_tp``: drop the ``data`` (FSDP) axis — weights replicated across
    data, sharded over model only.  No per-use weight all-gathers; right for
    decode when params/model_axis fits HBM (see EXPERIMENTS.md §Perf)."""
    def one(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh)
        if serve_tp:
            spec = P(*[None if ax == "data" else ax for ax in spec])
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# batch & cache specs
# ---------------------------------------------------------------------------

def batch_spec(name: str, shape: tuple, mesh) -> P:
    ba = batch_axes(mesh)
    if len(shape) == 0:
        return P()
    full = (ba,) + (None,) * (len(shape) - 1)
    return _guard(full, shape, mesh)


def batch_shardings(batch_shape: dict, mesh) -> dict:
    return {k: NamedSharding(mesh, batch_spec(k, v.shape, mesh))
            for k, v in batch_shape.items()}


def _kv_spec(shape: tuple, mesh, *, mla: bool) -> P:
    """KV cache: heads over model when divisible, else sequence over model.

    GQA: (.., B, S, Hkv, Dh); MLA compressed: (.., B, S, R) — MLA always
    shards S over model (the compressed dim R is the whole point of MLA).
    """
    ba = batch_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes.get("model", 1)
    nd = len(shape)
    if mla:
        full = (None,) * (nd - 3) + (ba, "model", None)
    else:
        hkv = shape[-2]
        if hkv % msize == 0:
            full = (None,) * (nd - 4) + (ba, None, "model", None)
        else:
            full = (None,) * (nd - 4) + (ba, "model", None, None)
    return _guard(full, shape, mesh)


def cache_shardings(cache_shape: Any, mesh) -> Any:
    """Walk an ``init_cache``-shaped tree, dispatching on cache node types."""
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMCache
    ba = batch_axes(mesh)

    def walk(node):
        if isinstance(node, KVCache):
            # GQA: k/v identical (.., S, Hkv, Dh); MLA: k=(..,S,R), v=(..,S,dr)
            is_gqa = node.k.ndim >= 4 and node.k.shape == node.v.shape
            return KVCache(
                NamedSharding(mesh, _kv_spec(node.k.shape, mesh, mla=not is_gqa)),
                NamedSharding(mesh, _kv_spec(node.v.shape, mesh, mla=not is_gqa)))
        if isinstance(node, SSMCache):
            conv_full = ((None,) * (node.conv.ndim - 3)
                         + (ba, None, "model"))
            state_full = ((None,) * (node.state.ndim - 4)
                          + (ba, "model", None, None))
            return SSMCache(
                NamedSharding(mesh, _guard(conv_full, node.conv.shape, mesh)),
                NamedSharding(mesh, _guard(state_full, node.state.shape, mesh)))
        if isinstance(node, (list, tuple)):
            return type(node)(walk(x) for x in node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if node is None:
            return None
        # bare array (e.g. encoder output threaded through serve state)
        shp = node.shape
        full = (ba,) + (None,) * (len(shp) - 1)
        return NamedSharding(mesh, _guard(full, shp, mesh))

    return walk(cache_shape)


def scalar_sharding(mesh):
    return NamedSharding(mesh, P())
