"""repro: LUNA-CIM (LUT-based programmable neural processing) as a JAX/TPU framework.

Layers (bottom-up):
  core      — the paper's contribution: D&C LUT multiplication, quantization,
              hardware cost model, LunaDense layers.
  kernels   — Pallas TPU kernels for the perf-critical paths.
  models    — the 10 assigned architectures + the paper's own eval net.
  parallel  — sharding rules, compressed collectives, pipeline parallelism.
  data/optim/checkpoint/train/serve — training & serving substrates.
  configs   — per-architecture configs and input shapes.
  launch    — mesh construction, multi-pod dry-run, roofline, CLIs.
"""

__version__ = "0.1.0"
