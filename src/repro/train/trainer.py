"""Fault-tolerant training loop: checkpoint/restart, preemption handling,
straggler detection.

Designed for the restart-based recovery model of 1000+ node fleets:
  * auto-resume from the latest complete checkpoint on (re)start;
  * SIGTERM/SIGINT -> synchronous final checkpoint then clean exit
    (preemption-notice handling);
  * per-step wall-time watchdog with EMA outlier detection (the straggler
    signal that triggers drain/replace on a real fleet; here it logs and
    counts events);
  * deterministic data stream keyed by step — a restart replays nothing and
    needs no data-state checkpoint;
  * elastic: checkpoints restore onto any mesh (device count can change
    between runs — see checkpoint/ckpt.py).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass

import jax

from repro.checkpoint.ckpt import Checkpointer
from repro.data.synthetic import SyntheticLM
from repro.models.registry import get_model
from repro.optim.adamw import AdamW, AdamWState, cosine_schedule
from repro.parallel import sharding as shd
from repro.parallel.act_sharding import activation_sharding
from repro.train.train_step import make_train_step


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    lr: float = 3e-4
    warmup: int = 10
    straggler_factor: float = 3.0   # step > factor * EMA -> straggler event
    microbatch: int = 0
    grad_compression: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, cfg, tcfg: TrainerConfig, mesh):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.model = get_model(cfg)
        self.opt = AdamW(lr=tcfg.lr,
                         schedule=cosine_schedule(tcfg.warmup,
                                                  tcfg.total_steps))
        self.ckpt = Checkpointer(tcfg.ckpt_dir)
        self._stop = False
        self.straggler_events: list[int] = []

    def _install_signals(self):
        def handler(signum, frame):
            self._stop = True      # finish current step, checkpoint, exit
        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def run(self, data: SyntheticLM, *, install_signals: bool = True):
        tcfg = self.tcfg
        if install_signals:
            self._install_signals()

        params_shape = jax.eval_shape(
            lambda: self.model.init(jax.random.PRNGKey(tcfg.seed)))
        opt_shape = jax.eval_shape(self.opt.init, params_shape)
        p_sh = shd.param_shardings(params_shape, self.mesh)
        opt_sh = AdamWState(shd.scalar_sharding(self.mesh), p_sh, p_sh)
        state_shape = {"params": params_shape, "opt": opt_shape}
        state_sh = {"params": p_sh, "opt": opt_sh}

        step_fn, _ = make_train_step(
            self.cfg, self.opt, self.mesh, microbatch=tcfg.microbatch,
            grad_compression=tcfg.grad_compression)

        start = self.ckpt.latest_step()
        with self.mesh, activation_sharding(self.mesh):
            if start is None:
                params = jax.jit(self.model.init, out_shardings=p_sh)(
                    jax.random.PRNGKey(tcfg.seed))
                opt_state = jax.jit(self.opt.init, out_shardings=opt_sh)(
                    params)
                start = 0
            else:
                state = self.ckpt.restore(start, state_shape, state_sh)
                params, opt_state = state["params"], state["opt"]
                opt_state = AdamWState(*opt_state) \
                    if not isinstance(opt_state, AdamWState) else opt_state
                print(f"[trainer] resumed from step {start}", flush=True)

            jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
            ema = None
            history = []
            for step in range(start, tcfg.total_steps):
                t0 = time.time()
                batch = data.batch(step)
                params, opt_state, metrics = jit_step(params, opt_state,
                                                      batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                if ema is not None and dt > tcfg.straggler_factor * ema:
                    self.straggler_events.append(step)
                    print(f"[watchdog] step {step} took {dt:.2f}s "
                          f"(EMA {ema:.2f}s) — straggler/retry signal",
                          flush=True)
                ema = dt if ema is None else 0.9 * ema + 0.1 * dt
                history.append(loss)
                if step % tcfg.log_every == 0:
                    print(f"[trainer] step {step} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
                done = step + 1
                if (done % tcfg.ckpt_every == 0 or self._stop
                        or done == tcfg.total_steps):
                    self.ckpt.save(done, {"params": params,
                                          "opt": opt_state},
                                   blocking=self._stop)
                if self._stop:
                    print(f"[trainer] preemption: checkpointed at {done}",
                          flush=True)
                    break
            self.ckpt.wait()
        return params, history
