"""Train/serve step factories with full sharding annotations.

``make_train_step``/``make_serve_step`` return jit'd functions plus the
in_shardings used — the dry-run lowers exactly these artifacts.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.registry import get_model, input_specs
from repro.optim.adamw import AdamW, AdamWState
from repro.parallel import sharding as shd


def loss_fn_of(model, cfg):
    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics
    return loss_fn


def make_train_step(cfg, optimizer: AdamW, mesh, *, microbatch: int = 0,
                    grad_compression: bool = False):
    """Returns (train_step, shardings dict).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics).
    ``microbatch``: if > 0, split the per-step batch into that many
    accumulation chunks (overlaps the DP gradient reduction of chunk i-1
    with compute of chunk i under XLA latency hiding).
    """
    model = get_model(cfg)
    loss_fn = loss_fn_of(model, cfg)

    def train_step(params, opt_state, batch):
        if microbatch > 1:
            def one(carry, mb):
                acc, _ = carry
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatch, -1) + x.shape[1:]), batch)
            (gsum, last_loss), _ = jax.lax.scan(one, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss, metrics = last_loss, {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        if grad_compression:
            from repro.parallel.collectives import compress_grads_int8
            grads = compress_grads_int8(grads)
        new_params, new_opt, opt_metrics = optimizer.update(
            grads, opt_state, params)
        metrics = dict(metrics or {}, loss=loss, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step, model


def train_shardings(model, cfg, shape, mesh):
    """(params, opt_state, batch) NamedShardings for the dry-run lowering."""
    params_shape = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = shd.param_shardings(params_shape, mesh)
    opt_sh = AdamWState(shd.scalar_sharding(mesh), p_sh, p_sh)
    batch_shape = input_specs(cfg, shape)
    b_sh = shd.batch_shardings(batch_shape, mesh)
    return params_shape, p_sh, opt_sh, batch_shape, b_sh


def make_serve_step(cfg, mesh, *, kind: str, shape):
    """Returns model + (prefill | decode) callable for lowering."""
    model = get_model(cfg)
    if kind == "prefill":
        def serve_step(params, batch, caches):
            kwargs = {k: v for k, v in batch.items()
                      if k in ("frames", "patches")}
            return model.prefill(params, batch["tokens"], caches, **kwargs)
        return model, serve_step
    # decode: one token against a seq_len cache
    def serve_step(params, token, caches, index):
        return model.decode_step(params, token, caches, index)
    return model, serve_step
