"""LUNA-CIM core arithmetic: divide-and-conquer LUT multiplication.

The paper decomposes an ``n``-bit multiplication ``W x Y`` (weight-stationary)
into radix-4 digits of the *input* ``Y``::

    W * Y = sum_d (W * y_d) << (2*d),        y_d in {0,1,2,3}

Each partial product ``W * y_d`` is a lookup into the 4-entry table
``{0, W, W<<1, 3W}`` (paper Figs 2/3).  The approximation variants replace the
lowest digit's partial product:

    ApproxD&C  (paper Figs 4-9):  Z_LSB := 0   (Hamming-optimal constant)
    ApproxD&C2 (paper Figs 10-12): Z_LSB := W  (pretend y_lo == 01)

TPU adaptation (see DESIGN.md section 2): the digit split becomes *digit-plane
int8 matmuls* on the MXU; ApproxD&C drops the low plane (halves MXU work);
ApproxD&C2's contribution is ``colsum(W)`` — a precomputed bias.

Everything in this module is bit-exact integer arithmetic on *unsigned code*
tensors (int32 carriers).  Real-valued layers live in ``core.layers``; the
Pallas kernels in ``repro.kernels.luna_mm`` implement the same semantics with
VMEM tiling and are validated against this module.
"""
from __future__ import annotations

import enum
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

DIGIT_BITS = 2  # the paper's radix-4 split
RADIX = 1 << DIGIT_BITS


class LunaMode(str, enum.Enum):
    """Multiplier variants, one per paper figure."""

    CONVENTIONAL = "conventional"  # Fig 1: full 2^n-entry LUT (exact)
    DC = "dc"                      # Fig 2: divide & conquer (exact)
    OPT_DC = "opt_dc"              # Fig 3: optimized storage D&C (exact)
    APPROX_DC = "approx_dc"        # Figs 4/9: Z_LSB := 0
    APPROX_DC2 = "approx_dc2"      # Fig 10: Z_LSB := W

    @property
    def is_exact(self) -> bool:
        return self in (LunaMode.CONVENTIONAL, LunaMode.DC, LunaMode.OPT_DC)


def num_digits(bits: int, digit_bits: int = DIGIT_BITS) -> int:
    if bits % digit_bits:
        raise ValueError(f"bits={bits} not divisible by digit_bits={digit_bits}")
    return bits // digit_bits


def split_digits(codes: jax.Array, bits: int, digit_bits: int = DIGIT_BITS) -> list[jax.Array]:
    """Split unsigned codes into radix-``2**digit_bits`` digits, LSB first."""
    mask = (1 << digit_bits) - 1
    return [(codes >> (digit_bits * d)) & mask for d in range(num_digits(bits, digit_bits))]


def combine_partials(partials: Sequence[jax.Array], digit_bits: int = DIGIT_BITS) -> jax.Array:
    """Shift-add combine of per-digit partial products (LSB first).

    This is the paper's HA/FA adder tree; on TPU it is int32 adds.
    """
    out = partials[0]
    for d, pp in enumerate(partials[1:], start=1):
        out = out + (pp << (digit_bits * d))
    return out


# ---------------------------------------------------------------------------
# Element-wise multiplier semantics (the paper's single LUNA unit)
# ---------------------------------------------------------------------------

def luna_product(w: jax.Array, y: jax.Array, bits: int = 4,
                 mode: LunaMode = LunaMode.OPT_DC,
                 digit_bits: int = DIGIT_BITS) -> jax.Array:
    """Element-wise ``W*Y`` with the selected LUNA multiplier variant.

    ``w``/``y`` are unsigned integer codes in ``[0, 2**bits)``.  Exact modes
    return the true product; approx modes return the paper's approximation.
    """
    mode = LunaMode(mode)
    w = w.astype(jnp.int32)
    y = y.astype(jnp.int32)
    digits = split_digits(y, bits, digit_bits)
    partials = [w * d for d in digits]
    if mode == LunaMode.APPROX_DC:
        partials[0] = jnp.zeros_like(partials[0])
    elif mode == LunaMode.APPROX_DC2:
        partials[0] = w
    return combine_partials(partials, digit_bits)


# ---------------------------------------------------------------------------
# Matmul semantics (a LUNA array: one unit per (k, n) weight)
# ---------------------------------------------------------------------------

def _plane_matmul(y_plane: jax.Array, w: jax.Array, bits: int) -> jax.Array:
    """Digit-plane matmul (the MXU-mapped lookup): int8 x int8 -> int32.

    The digit plane is always in {0..3}; the weight codes fit int8 for
    bits <= 7 (the MXU int8 path).  Wider weights keep an int32 carrier —
    the paper's LUT stores full-width entries, only Y is digit-split.
    """
    wt = jnp.int8 if bits <= 7 else jnp.int32
    return jax.lax.dot_general(
        y_plane.astype(wt), w.astype(wt),
        dimension_numbers=(((y_plane.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def luna_matmul(y_codes: jax.Array, w_codes: jax.Array, bits: int = 4,
                mode: LunaMode = LunaMode.OPT_DC,
                digit_bits: int = DIGIT_BITS) -> jax.Array:
    """``Z[m, n] = sum_k luna_product(W[k, n], Y[m, k])`` in int32.

    The digit decomposition commutes with the contraction: each digit plane of
    Y contracts against W in a separate low-precision matmul and the shift-add
    happens once on the int32 accumulators.  For the approx modes the low
    plane is dropped (APPROX_DC) or replaced by ``colsum(W)`` broadcast over
    rows (APPROX_DC2) — zero runtime cost on TPU.
    """
    mode = LunaMode(mode)
    planes = split_digits(y_codes.astype(jnp.int32), bits, digit_bits)
    acc = jnp.zeros(y_codes.shape[:-1] + (w_codes.shape[-1],), jnp.int32)
    for d in range(len(planes)):
        if d == 0:
            if mode == LunaMode.APPROX_DC:
                continue
            if mode == LunaMode.APPROX_DC2:
                colsum = jnp.sum(w_codes.astype(jnp.int32), axis=0)
                acc = acc + colsum  # broadcast over leading dims
                continue
        acc = acc + (_plane_matmul(planes[d], w_codes, bits) << (digit_bits * d))
    return acc


# ---------------------------------------------------------------------------
# Optimized-storage table reconstruction (paper Fig 3) — used by tests and
# the cost model to prove the 10-SRAM-cell claim is information-complete.
# ---------------------------------------------------------------------------

def optimized_table_storage(w: int, bits: int = 4) -> dict:
    """Return the *stored bits* of the optimized D&C table for weight ``w``.

    Paper Fig 3: of the 4-entry table {0, W, 2W, 3W} only ``1 + bits +
    (bits+1)`` bits are stored: one literal 0, the ``bits`` bits of W, and the
    ``bits+1`` MSBs of 3W (the LSB of 3W equals the LSB of W).
    """
    assert 0 <= w < (1 << bits)
    t3 = 3 * w
    return {
        "zero_bit": 0,
        "w_bits": w,                      # `bits` cells
        "t3_msbs": t3 >> 1,               # `bits + 1` cells
        "num_cells": 1 + bits + (bits + 1),
    }


def optimized_table_reconstruct(storage: dict, bits: int = 4) -> list[int]:
    """Rebuild the full 4-entry table from the stored bits (Fig 3 wiring)."""
    w = storage["w_bits"]
    t3 = (storage["t3_msbs"] << 1) | (w & 1)  # LSB of 3W == LSB of W
    return [0, w, w << 1, t3]


# ---------------------------------------------------------------------------
# Statistical analyses (paper Figs 5, 6, 7/8, 11/12)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def lsb_product_distribution(bits: int = 4, digit_bits: int = DIGIT_BITS):
    """Fig 5: distribution of the LSB-side product ``W * y_lo``.

    W uniform over [0, 2**bits), y_lo uniform over [0, 2**digit_bits).
    Returns (values 0..max, probabilities).  P(0) = 0.296 for 4b.
    """
    ws = np.arange(1 << bits)
    ys = np.arange(1 << digit_bits)
    prods = (ws[:, None] * ys[None, :]).ravel()
    max_val = ((1 << bits) - 1) * ((1 << digit_bits) - 1)
    n_out_bits = bits + digit_bits
    counts = np.bincount(prods, minlength=1 << n_out_bits)
    return np.arange(1 << n_out_bits), counts / counts.sum(), max_val


def impossible_lsb_products(bits: int = 4, digit_bits: int = DIGIT_BITS) -> list[int]:
    """Values in [0, 2**(bits+digit_bits)) that ``W*y_lo`` can never produce."""
    vals, probs, _ = lsb_product_distribution(bits, digit_bits)
    return [int(v) for v, p in zip(vals, probs) if p == 0.0]


def hamming_distance_profile(bits: int = 4, digit_bits: int = DIGIT_BITS):
    """Fig 6: mean per-bit Hamming distance of each candidate constant vs the
    true LSB product, weighted by the product distribution.

    The paper reports the *fraction of differing bits* (6-bit strings):
    argmin is 0 with mean HD 0.275 for 4b (= 1.656 differing bits / 6).
    """
    vals, probs, _ = lsb_product_distribution(bits, digit_bits)
    n_out_bits = bits + digit_bits
    cands = np.arange(1 << n_out_bits)
    xor = cands[:, None] ^ vals[None, :]
    hd = np.zeros_like(xor, dtype=np.float64)
    for b in range(n_out_bits):
        hd += (xor >> b) & 1
    return cands, (hd * probs[None, :]).sum(axis=1) / n_out_bits


def error_table(mode: LunaMode, bits: int = 4) -> np.ndarray:
    """Figs 7/11: error surface ``exact - approx`` over all (W, Y) codes.

    Paper convention (Figs 8/12 histograms): ApproxD&C error in [0, 45],
    ApproxD&C2 error in [-15, 30] for 4b.
    """
    n = 1 << bits
    w = jnp.arange(n, dtype=jnp.int32)[:, None]
    y = jnp.arange(n, dtype=jnp.int32)[None, :]
    exact = w * y
    approx = luna_product(jnp.broadcast_to(w, (n, n)),
                          jnp.broadcast_to(y, (n, n)), bits, mode)
    return np.asarray(exact - approx)


def mean_abs_error(mode: LunaMode, bits: int = 4) -> float:
    """Expected |error| under uniform codes — the analytic core of Fig 13."""
    return float(np.abs(error_table(LunaMode(mode), bits)).mean())
