"""Quantization substrate: affine quantizers, calibration, and the real-valued
LUNA matmul (integer core + zero-point corrections + STE for QAT).

The paper's operands are unsigned 4-bit codes.  Real tensors are mapped to
unsigned codes with asymmetric affine quantization::

    x ~= s_x * (q_x - z_x),   q_x in [0, 2**bits)

and the matmul identity (standard integer-GEMM algebra) recovers the real
product from the code-space LUNA accumulation::

    x @ w ~= s_x s_w [ L(q_x, q_w) - z_x colsum(q_w) - rowsum(q_x) z_w
                       + K z_x z_w ]

where ``L`` is ``luna_matmul`` in any mode.  For approx modes the paper's
code-space error flows through the same identity scaled by ``s_x s_w`` —
which is exactly how the paper's Fig 13 NN-level MAE arises.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.luna import LunaMode, luna_matmul


class QParams(NamedTuple):
    scale: jax.Array       # per-tensor () or per-channel (N,)
    zero_point: jax.Array  # same shape as scale, unsigned-code zero point
    bits: int


def calibrate(x: jax.Array, bits: int = 4, axis: int | None = None,
              symmetric: bool = False) -> QParams:
    """Min/max affine calibration to unsigned codes.

    ``axis``: reduction keeps this axis (per-channel); None = per-tensor.
    ``symmetric``: centers the range on 0 (zero_point at mid-code).
    """
    qmax = (1 << bits) - 1
    if axis is None:
        lo = jnp.min(x)
        hi = jnp.max(x)
    else:
        red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        lo = jnp.min(x, axis=red)
        hi = jnp.max(x, axis=red)
    if symmetric:
        amax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        lo, hi = -amax, amax
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.clip(jnp.round(-lo / scale), 0, qmax)
    return QParams(scale.astype(jnp.float32), zp.astype(jnp.float32), bits)


def quantize(x: jax.Array, qp: QParams) -> jax.Array:
    """Real -> unsigned integer codes (int32 carrier)."""
    qmax = (1 << qp.bits) - 1
    codes = jnp.round(x / qp.scale + qp.zero_point)
    return jnp.clip(codes, 0, qmax).astype(jnp.int32)


def dequantize(codes: jax.Array, qp: QParams) -> jax.Array:
    return (codes.astype(jnp.float32) - qp.zero_point) * qp.scale


def quant_error(x: jax.Array, qp: QParams) -> jax.Array:
    return dequantize(quantize(x, qp), qp) - x


# ---------------------------------------------------------------------------
# Real-valued LUNA matmul
# ---------------------------------------------------------------------------

def luna_matmul_f32(x: jax.Array, w: jax.Array, mode: LunaMode | str,
                    bits: int = 4, x_qp: QParams | None = None,
                    w_qp: QParams | None = None) -> jax.Array:
    """Float-in/float-out matmul with LUNA integer arithmetic inside.

    ``x``: (..., K); ``w``: (K, N).  Dynamic per-tensor activation quant,
    per-output-channel weight quant unless QParams are provided (static PTQ).
    """
    mode = LunaMode(mode)
    x_qp = x_qp or calibrate(x, bits, axis=None)
    w_qp = w_qp or calibrate(w, bits, axis=-1)
    qx = quantize(x, x_qp)
    qw = quantize(w, w_qp)
    k = x.shape[-1]

    acc = luna_matmul(qx, qw, bits=bits, mode=mode).astype(jnp.float32)
    colsum_qw = jnp.sum(qw, axis=0).astype(jnp.float32)           # (N,)
    rowsum_qx = jnp.sum(qx, axis=-1, keepdims=True).astype(jnp.float32)
    zx, zw = x_qp.zero_point, w_qp.zero_point
    corrected = (acc
                 - zx * colsum_qw
                 - rowsum_qx * zw
                 + k * zx * zw)
    return (x_qp.scale * w_qp.scale) * corrected


# ---------------------------------------------------------------------------
# QAT: straight-through estimator — forward runs the exact LUNA integer path,
# backward pretends it was a plain matmul.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def ste_luna_matmul(x: jax.Array, w: jax.Array, mode: str, bits: int = 4):
    return luna_matmul_f32(x, w, mode, bits)


def _ste_fwd(x, w, mode, bits):
    return luna_matmul_f32(x, w, mode, bits), (x, w)


def _ste_bwd(mode, bits, res, g):
    x, w = res
    gx = jnp.einsum("...n,kn->...k", g, w)
    batch = x.reshape(-1, x.shape[-1])
    gw = batch.T @ g.reshape(-1, g.shape[-1])
    return gx.astype(x.dtype), gw.astype(w.dtype)


ste_luna_matmul.defvjp(_ste_fwd, _ste_bwd)
