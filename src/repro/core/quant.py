"""Quantization substrate: affine quantizers, calibration, and the real-valued
LUNA matmul (integer core + zero-point corrections + STE for QAT).

The paper's operands are unsigned 4-bit codes.  Real tensors are mapped to
unsigned codes with asymmetric affine quantization::

    x ~= s_x * (q_x - z_x),   q_x in [0, 2**bits)

and the matmul identity (standard integer-GEMM algebra) recovers the real
product from the code-space LUNA accumulation::

    x @ w ~= s_x s_w [ L(q_x, q_w) - z_x colsum(q_w) - rowsum(q_x) z_w
                       + K z_x z_w ]

where ``L`` is ``luna_matmul`` in any mode.  For approx modes the paper's
code-space error flows through the same identity scaled by ``s_x s_w`` —
which is exactly how the paper's Fig 13 NN-level MAE arises.

Serving-side weight-only quantization (this module's second half) applies
the same algebra statically: :class:`QuantizedWeight` freezes a projection
into 4-bit codes + per-channel :class:`QParams` at engine construction, and
:func:`quantize_decode_params` walks a model param tree replacing every
decode-projection leaf.  The D&C sub-tables stored alongside the codes are
the paper's Fig 2/3 decomposition of the 16-entry code LUT: a 4-bit code
``q`` splits into 2-bit digits ``q = 4*q_hi + q_lo``, so the 16-entry table
is evaluated as the sum of two 4-entry sub-tables (``HI[i] = 4i``,
``LO[j] = j``) — 2 × (2**2 − 1) = 6 mux selects instead of 15, the select
cost behind the paper's ~3.7× area saving.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.luna import LunaMode, luna_matmul


class QParams(NamedTuple):
    scale: jax.Array       # per-tensor () or per-channel (N,)
    zero_point: jax.Array  # same shape as scale, unsigned-code zero point
    bits: int


def calibrate(x: jax.Array, bits: int = 4, axis: int | None = None,
              symmetric: bool = False) -> QParams:
    """Min/max affine calibration to unsigned codes.

    ``axis``: reduction keeps this axis (per-channel); None = per-tensor.
    ``symmetric``: centers the range on 0 (zero_point at mid-code).
    """
    qmax = (1 << bits) - 1
    if axis is None:
        lo = jnp.min(x)
        hi = jnp.max(x)
    else:
        red = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
        lo = jnp.min(x, axis=red)
        hi = jnp.max(x, axis=red)
    if symmetric:
        amax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        lo, hi = -amax, amax
    scale = jnp.maximum(hi - lo, 1e-8) / qmax
    zp = jnp.clip(jnp.round(-lo / scale), 0, qmax)
    return QParams(scale.astype(jnp.float32), zp.astype(jnp.float32), bits)


def quantize(x: jax.Array, qp: QParams) -> jax.Array:
    """Real -> unsigned integer codes (int32 carrier)."""
    qmax = (1 << qp.bits) - 1
    codes = jnp.round(x / qp.scale + qp.zero_point)
    return jnp.clip(codes, 0, qmax).astype(jnp.int32)


def dequantize(codes: jax.Array, qp: QParams) -> jax.Array:
    return (codes.astype(jnp.float32) - qp.zero_point) * qp.scale


def quant_error(x: jax.Array, qp: QParams) -> jax.Array:
    return dequantize(quantize(x, qp), qp) - x


# ---------------------------------------------------------------------------
# Real-valued LUNA matmul
# ---------------------------------------------------------------------------

def luna_matmul_f32(x: jax.Array, w: jax.Array, mode: LunaMode | str,
                    bits: int = 4, x_qp: QParams | None = None,
                    w_qp: QParams | None = None) -> jax.Array:
    """Float-in/float-out matmul with LUNA integer arithmetic inside.

    ``x``: (..., K); ``w``: (K, N).  Dynamic per-tensor activation quant,
    per-output-channel weight quant unless QParams are provided (static PTQ).
    """
    mode = LunaMode(mode)
    x_qp = x_qp or calibrate(x, bits, axis=None)
    w_qp = w_qp or calibrate(w, bits, axis=-1)
    qx = quantize(x, x_qp)
    qw = quantize(w, w_qp)
    k = x.shape[-1]

    acc = luna_matmul(qx, qw, bits=bits, mode=mode).astype(jnp.float32)
    colsum_qw = jnp.sum(qw, axis=0).astype(jnp.float32)           # (N,)
    rowsum_qx = jnp.sum(qx, axis=-1, keepdims=True).astype(jnp.float32)
    zx, zw = x_qp.zero_point, w_qp.zero_point
    corrected = (acc
                 - zx * colsum_qw
                 - rowsum_qx * zw
                 + k * zx * zw)
    return (x_qp.scale * w_qp.scale) * corrected


# ---------------------------------------------------------------------------
# QAT: straight-through estimator — forward runs the exact LUNA integer path,
# backward pretends it was a plain matmul.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def ste_luna_matmul(x: jax.Array, w: jax.Array, mode: str, bits: int = 4):
    return luna_matmul_f32(x, w, mode, bits)


def _ste_fwd(x, w, mode, bits):
    return luna_matmul_f32(x, w, mode, bits), (x, w)


def _ste_bwd(mode, bits, res, g):
    x, w = res
    gx = jnp.einsum("...n,kn->...k", g, w)
    batch = x.reshape(-1, x.shape[-1])
    gw = batch.T @ g.reshape(-1, g.shape[-1])
    return gx.astype(x.dtype), gw.astype(w.dtype)


ste_luna_matmul.defvjp(_ste_fwd, _ste_bwd)


# ---------------------------------------------------------------------------
# Serving-side weight-only quantization: frozen 4-bit decode weights.
# ---------------------------------------------------------------------------

#: evaluation strategies for a frozen 4-bit weight (EngineConfig(quant=...)):
#: "lut_dc" sums the paper's two 2-bit D&C sub-tables through the mux tree;
#: "dequant" is the conventional-math baseline (direct affine dequant).
#: Both reconstruct the identical affine grid — tokens match bit-for-bit.
#: "nf4_dc" evaluates the NON-AFFINE NF4 codebook as HI + LO + a per-code
#: residual correction (the least-squares D&C split of core.lut, possibly
#: pruned); "nf4_dequant" is its conventional baseline (direct 16-entry
#: codebook lookup — the oracle the residual path is pinned against).
WEIGHT_KERNELS = ("lut_dc", "dequant", "nf4_dc", "nf4_dequant")

#: default |residual| magnitude threshold for pruned sub-tables
#: (quant="nf4p"): keeps exactly half the NF4 residual table's 16 entries
#: — the capacity/accuracy operating point reported in the benches.
NF4P_PRUNE_THRESHOLD = 0.05


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class QuantizedWeight:
    """A projection weight frozen to unsigned 4-bit codes (paper Sec. III).

    ``codes``: (..., K, N) int8 codes in [0, 16); ``scale``/``zero_point``:
    (..., N) per-output-channel affine params from :func:`calibrate`;
    ``hi_tab``/``lo_tab``: (..., 4) D&C sub-tables in code space
    (``q = hi_tab[q >> 2] + lo_tab[q & 3]`` exactly for the affine kernels
    — the Fig 2/3 split of the 16-entry LUT into two 4-entry tables).
    ``residual``: ``None`` for affine kernels (the split is exact); for the
    non-affine NF4 kernels a (..., 16) per-code correction table
    (``cb[q] ~= hi_tab[q >> 2] + lo_tab[q & 3] + residual[q]``), dense or
    pruned-to-zero below the magnitude threshold (see
    :func:`repro.core.lut.prune_residual`).  ``kernel`` is static pytree
    aux data selecting the evaluation strategy (see ``WEIGHT_KERNELS``).

    Registered as a pytree so a stacked instance (leading layer axis on
    every array child) slices cleanly under ``jax.lax.scan`` and traces
    through ``jax.jit`` like any other param leaf (a ``None`` residual is
    an empty subtree, so affine instances flatten exactly as before).
    """
    codes: jax.Array
    scale: jax.Array
    zero_point: jax.Array
    hi_tab: jax.Array
    lo_tab: jax.Array
    residual: jax.Array | None = None
    kernel: str = "lut_dc"

    def tree_flatten(self):
        return ((self.codes, self.scale, self.zero_point,
                 self.hi_tab, self.lo_tab, self.residual), self.kernel)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, kernel=aux)

    @property
    def qparams(self) -> QParams:
        return QParams(self.scale, self.zero_point, 4)


def _nf4_dc_tables(prune_threshold: float | None):
    """(hi, lo, residual) least-squares D&C split of the NF4 codebook,
    residual optionally pruned to the kept-set sparse gather (dropped
    codes read 0 and fall through to the pure HI + LO sum)."""
    from repro.core.lut import (NF4_CODEBOOK, dc_decompose_codebook,
                                prune_residual, scatter_residual)
    hi_tab, lo_tab, residual = dc_decompose_codebook(jnp.asarray(NF4_CODEBOOK))
    if prune_threshold is not None:
        kept_idx, kept_val = prune_residual(residual, prune_threshold)
        residual = scatter_residual(kept_idx, kept_val)
    return hi_tab, lo_tab, residual


def quantize_weight(w: jax.Array, kernel: str = "lut_dc",
                    prune_threshold: float | None = None) -> QuantizedWeight:
    """Freeze a (…, K, N) float weight to a :class:`QuantizedWeight`.

    Affine kernels (``"lut_dc"`` / ``"dequant"``) calibrate per output
    channel over the K axis (the paper's operands are unsigned codes; see
    the module docstring identity) and carry the exact code-space split
    ``HI[i] = 4i``, ``LO[j] = j`` with no residual.  The NF4 kernels
    (``"nf4_dc"`` / ``"nf4_dequant"``) encode against the non-affine NF4
    codebook with per-output-channel absmax scaling (the codebook is
    symmetric on [-1, 1], so ``zero_point`` is 0) and carry the
    least-squares D&C split of the codebook plus its per-code residual —
    pruned below ``prune_threshold`` when given (``quant="nf4p"``).

    Leaves with extra leading axes (scan-stacked layers) are quantized
    per-slice by vmapping, so every array child carries the same leading
    axes and the container remains ``jax.lax.scan``-sliceable.
    """
    if kernel not in WEIGHT_KERNELS:
        raise ValueError(f"unknown weight kernel {kernel!r}; "
                         f"one of {WEIGHT_KERNELS}")
    if w.ndim > 2:
        return jax.vmap(
            lambda wi: quantize_weight(wi, kernel, prune_threshold))(w)
    wf = w.astype(jnp.float32)
    if kernel in ("nf4_dc", "nf4_dequant"):
        from repro.core.lut import NF4_CODEBOOK
        cb = jnp.asarray(NF4_CODEBOOK)
        scale = jnp.maximum(jnp.max(jnp.abs(wf), axis=0), 1e-8)
        wn = wf / scale[None, :]
        codes = jnp.argmin(jnp.abs(wn[..., None] - cb), axis=-1)
        hi_tab, lo_tab, residual = _nf4_dc_tables(prune_threshold)
        return QuantizedWeight(codes.astype(jnp.int8),
                               scale.astype(jnp.float32),
                               jnp.zeros_like(scale, jnp.float32),
                               hi_tab, lo_tab, residual=residual,
                               kernel=kernel)
    qp = calibrate(wf, bits=4, axis=-1)
    codes = quantize(wf, qp).astype(jnp.int8)
    # D&C sub-tables (code space): q = HI[q>>2] + LO[q&3], HI[i]=4i, LO[j]=j.
    hi_tab = (4.0 * jnp.arange(4, dtype=jnp.float32))
    lo_tab = jnp.arange(4, dtype=jnp.float32)
    return QuantizedWeight(codes, qp.scale, qp.zero_point,
                           hi_tab, lo_tab, kernel=kernel)


#: decode-projection leaf names eligible for engine-level quantization.
#: Everything here is consumed through ``core.layers.quant_matmul``; leaves
#: used directly (MLA's w_uk/w_uv reshapes, MoE routed-expert einsums,
#: routers, norms, embeddings, lm_head) are deliberately absent.
DECODE_QUANT_TARGETS = frozenset({
    "wq", "wk", "wv", "wo", "w_dq", "w_uq", "w_dkv",      # attention
    "w_up", "w_gate", "w_down",                            # mlp / shared moe
    "w_in", "w_out",                                       # mamba2 mixer
})

#: dict keys whose subtrees hold quant_matmul-consumed projections.  MoE
#: routed experts live directly under "moe" (stacked (E, ...) einsum
#: operands sharing the mlp leaf NAMES) — only the "shared" expert subtree
#: routes through quant_matmul, so parent-key scoping is load-bearing.
_QUANT_PARENT_KEYS = frozenset({"attn", "mlp", "m", "shared"})


#: EngineConfig(quant=...) mode -> (weight kernel, residual prune threshold).
#: ``nf4_direct`` is not an engine mode: it is the conventional full-table
#: NF4 dequant oracle the residual-corrected ``nf4`` path is pinned against
#: in tests and the fig13 harness.
DECODE_QUANT_KERNELS = {
    "lut4": ("lut_dc", None),
    "int4": ("dequant", None),
    "nf4": ("nf4_dc", None),
    "nf4p": ("nf4_dc", NF4P_PRUNE_THRESHOLD),
    "nf4_direct": ("nf4_dequant", None),
}


def quantize_decode_params(params, quant: str):
    """Walk a model param tree, freezing every decode projection to 4-bit.

    ``quant``: ``"lut4"`` (affine D&C sub-table LUT evaluation), ``"int4"``
    (direct-dequant baseline), ``"nf4"`` (non-affine NF4 codebook, D&C
    sub-tables + per-code residual correction), ``"nf4p"`` (same with the
    residual pruned below ``NF4P_PRUNE_THRESHOLD``), or ``"nf4_direct"``
    (full-table NF4 dequant — the test oracle, not an engine mode).  A
    leaf is quantized iff its dict key is in ``DECODE_QUANT_TARGETS``, some
    ancestor key is in the quant-parent set, and it is a float matrix —
    everything else (norms, embeddings, routers, MoE routed experts, MLA
    w_uk/w_uv) passes through untouched.
    """
    kernel, prune = DECODE_QUANT_KERNELS[quant]

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (k,)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            sub = [walk(v, path) for v in node]
            return type(node)(sub)
        if (path and path[-1] in DECODE_QUANT_TARGETS
                and any(p in _QUANT_PARENT_KEYS for p in path[:-1])
                and hasattr(node, "ndim") and node.ndim >= 2
                and jnp.issubdtype(node.dtype, jnp.floating)):
            return quantize_weight(node, kernel, prune)
        return node

    return walk(params, ())


#: the draft-weight mode for self-speculative decoding: the pruned-LUT NF4
#: tree is the cheapest decode path the engine owns, and LoCalut's
#: capacity-computation tradeoff says that is exactly where to spend the
#: draft budget — table bytes for draft throughput, full precision verifies.
SPEC_DRAFT_QUANT = "nf4p"


def quantize_draft_params(params, quant: str = SPEC_DRAFT_QUANT):
    """Draft-model weights for self-speculative decoding.

    The drafter is the SAME model with its decode projections frozen in
    their pruned-LUT form (default :data:`SPEC_DRAFT_QUANT`): no second
    set of trained weights, no separate cache layout — the draft step runs
    ``decode_step`` over this tree against a throwaway copy of the live
    caches while the full-precision tree scores the drafted window in one
    batched verify pass.  When the engine already decodes at the draft
    mode (``EngineConfig(quant="nf4p")``) the engine aliases its decode
    tree instead of calling this twice.
    """
    return quantize_decode_params(params, quant)
