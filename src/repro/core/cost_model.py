"""LUNA-CIM hardware cost model — reproduces the paper's Tables I/II and the
energy/area analyses (Figs 15/16/18).

Nothing here runs on TPU; it is the *paper-faithful* accounting of the SRAM
cells, 2:1 muxes and half/full adders each multiplier variant needs, plus a
TSMC-65nm-calibrated transistor/area/energy model.  All of the paper's stated
numbers are asserted in ``tests/test_cost_model.py``:

  Table I   — conventional LUT: 48/128/320/768/1792/4096 SRAMs for 3b..8b.
  Table II  — optimized D&C: (10, 36, 3, 3) @4b, (36, 120, 11, 21) @8b,
              (136, 432, 31, 105) @16b.
  Fig 15    — multiplier energy = 47.96 fJ = 0.0276 % of the 173.8 pJ/bit
              SRAM write energy.
  Fig 16    — optimized D&C ~3.7x smaller area than conventional LUT @4b.
  Fig 18    — 4 LUNA units on an 8x8 array = 32 % area overhead
              (4 x 287 um^2 of 3650 um^2).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.luna import LunaMode

# --- TSMC 65 nm calibration constants (documented model choices) -----------
TRANSISTORS = {
    "sram": 6,    # 6T SRAM cell
    "mux": 4,     # 2:1 pass-transistor mux
    "ha": 14,     # standard-cell half adder
    "fa": 28,     # standard-cell full adder
}
# Paper-measured constants (Section IV.B/IV.C):
E_SRAM_WRITE_PER_BIT_J = 173.8e-12   # J / bit / access, 8x8 array
E_MUX_MULTIPLIER_J = 47.96e-15       # J, 4b mux-based multiplier
LUNA_UNIT_AREA_UM2 = 287.0
ARRAY_WITH_4_UNITS_AREA_UM2 = 3650.0


@dataclass(frozen=True)
class HwCost:
    srams: int
    muxes: int   # 1-bit 2:1 muxes
    has: int
    fas: int

    @property
    def transistors(self) -> int:
        return (self.srams * TRANSISTORS["sram"] + self.muxes * TRANSISTORS["mux"]
                + self.has * TRANSISTORS["ha"] + self.fas * TRANSISTORS["fa"])

    def __add__(self, o: "HwCost") -> "HwCost":
        return HwCost(self.srams + o.srams, self.muxes + o.muxes,
                      self.has + o.has, self.fas + o.fas)


# ---------------------------------------------------------------------------
# Adder-tree construction (paper Figs 2/3 combine step, generalized).
#
# Combining partial sum A (width wa, at bit 0) with B (width wb, offset s):
#   * bit s                 : HA (A_s + B_0)
#   * bits s+1 .. wa-1      : FA (A, B, carry)          -> wa-1-s of them
#   * bits wa .. s+wb-1     : HA (B + carry ripple)     -> s+wb-wa of them
# The paper drops provably-zero-carry top HAs (its "101101" argument); the
# generic tree reproduces Table II exactly for 4/8/16 b as-is.
# ---------------------------------------------------------------------------

def _combine(wa: int, wb: int, s: int) -> tuple[int, int, int]:
    ha = 1 + (s + wb - wa)
    fa = wa - 1 - s
    return ha, fa, s + wb


def adder_tree_counts(num_digits: int, pp_width: int, digit_bits: int = 2
                      ) -> tuple[int, int]:
    """(HA, FA) to sum ``num_digits`` partial products of ``pp_width`` bits
    at stride ``digit_bits``, combined pairwise (binary tree)."""
    def rec(n: int) -> tuple[int, int, int]:
        if n == 1:
            return 0, 0, pp_width
        lo = n // 2
        ha_l, fa_l, w_l = rec(lo)
        ha_h, fa_h, w_h = rec(n - lo)
        ha, fa, w = _combine(w_l, w_h, digit_bits * lo)
        return ha_l + ha_h + ha, fa_l + fa_h + fa, w
    ha, fa, _ = rec(num_digits)
    return ha, fa


# ---------------------------------------------------------------------------
# Per-variant component counts
# ---------------------------------------------------------------------------

def conventional_cost(bits: int) -> HwCost:
    """Paper Fig 1 / Table I: full 2**bits-entry LUT of 2*bits-wide products."""
    n_entries, out_bits = 1 << bits, 2 * bits
    return HwCost(srams=n_entries * out_bits,
                  muxes=(n_entries - 1) * out_bits, has=0, fas=0)


def dc_cost(bits: int, digit_bits: int = 2) -> HwCost:
    """Paper Fig 2: D&C with one shared (fanout) 4-entry full table."""
    d = bits // digit_bits
    pp_w = bits + digit_bits
    srams = (1 << digit_bits) * pp_w          # 4 entries x (bits+2) bits
    muxes = d * ((1 << digit_bits) - 1) * pp_w
    ha, fa = adder_tree_counts(d, pp_w, digit_bits)
    return HwCost(srams, muxes, ha, fa)


def opt_dc_cost(bits: int, digit_bits: int = 2) -> HwCost:
    """Paper Fig 3 / Table II: optimized table = {0-bit, W, wired 2W, MSBs of
    3W}; one table set shared per *pair* of digit muxes (the paper's 4b slice
    structure)."""
    d = bits // digit_bits
    pp_w = bits + digit_bits
    pairs = (d + 1) // 2
    srams_per_set = 1 + bits + (bits + 1)     # 0, W, 3W-MSBs
    muxes = d * ((1 << digit_bits) - 1) * pp_w
    ha, fa = adder_tree_counts(d, pp_w, digit_bits)
    return HwCost(pairs * srams_per_set, muxes, ha, fa)


def approx_dc_cost(bits: int = 4, digit_bits: int = 2) -> HwCost:
    """Paper Fig 9: Z_LSB := 0 — the low digit's LUT, mux and all adders
    vanish (for 4b; for wider operands only the low digit is dropped)."""
    d = bits // digit_bits - 1
    pp_w = bits + digit_bits
    pairs = (d + 1) // 2
    muxes = d * ((1 << digit_bits) - 1) * pp_w
    ha, fa = adder_tree_counts(d, pp_w, digit_bits) if d > 1 else (0, 0)
    return HwCost(pairs * (1 + bits + bits + 1), muxes, ha, fa)


def approx_dc2_cost(bits: int = 4) -> HwCost:
    """Paper Fig 10 (4b): Z_LSB := W.  Counts stated in the paper: 12 SRAMs,
    18 muxes, 4 HA, 1 FA (top HA removed by the max-Z_MSB=101101 argument)."""
    if bits != 4:
        raise NotImplementedError("paper defines ApproxD&C2 for 4b")
    return HwCost(srams=12, muxes=18, has=4, fas=1)


def variant_cost(mode: LunaMode | str, bits: int = 4) -> HwCost:
    mode = LunaMode(mode)
    return {
        LunaMode.CONVENTIONAL: lambda: conventional_cost(bits),
        LunaMode.DC: lambda: dc_cost(bits),
        LunaMode.OPT_DC: lambda: opt_dc_cost(bits),
        LunaMode.APPROX_DC: lambda: approx_dc_cost(bits),
        LunaMode.APPROX_DC2: lambda: approx_dc2_cost(bits),
    }[mode]()


# ---------------------------------------------------------------------------
# Energy / area reports (Figs 15/16/18)
# ---------------------------------------------------------------------------

def energy_report() -> dict:
    """Fig 15 energy decomposition of the 8x8 array + multiplier.

    The two paper-measured anchors are the SRAM write energy/bit and the
    multiplier energy; the remaining component split is a documented model
    (bitline conditioning dominates SRAM write energy at 65 nm).
    """
    e_bit = E_SRAM_WRITE_PER_BIT_J
    share = E_MUX_MULTIPLIER_J / e_bit
    return {
        "sram_write_per_bit_J": e_bit,
        "mux_multiplier_J": E_MUX_MULTIPLIER_J,
        "multiplier_share": share,          # 0.000276 -> 0.0276 %
        "components_J": {                    # modeled split of e_bit
            "bitline_conditioning": 0.60 * e_bit,
            "sense_amplifiers": 0.15 * e_bit,
            "wordline_row_decoder": 0.06 * e_bit,
            "column_decoder_ctrl": 0.04 * e_bit,
            "cell_array": 0.15 * e_bit,
            "mux_multiplier": E_MUX_MULTIPLIER_J,
        },
    }


def area_report(bits: int = 4) -> dict:
    """Fig 16: transistor-count area comparison across variants."""
    out = {}
    for mode in LunaMode:
        c = variant_cost(mode, bits)
        out[mode.value] = {
            "srams": c.srams, "muxes": c.muxes, "has": c.has, "fas": c.fas,
            "transistors": c.transistors,
        }
    conv = out["conventional"]["transistors"]
    for mode in LunaMode:
        out[mode.value]["area_vs_conventional"] = conv / out[mode.value]["transistors"]
    return out


def array_overhead(num_units: int = 4) -> dict:
    """Fig 18: LUNA units added to the 8x8 SRAM array."""
    unit = LUNA_UNIT_AREA_UM2
    total = ARRAY_WITH_4_UNITS_AREA_UM2
    # Paper total is measured with 4 units; scale linearly in the model.
    sram_only = total - 4 * unit
    total_n = sram_only + num_units * unit
    return {
        "unit_area_um2": unit,
        "array_area_um2": sram_only,
        "total_area_um2": total_n,
        "overhead_fraction": num_units * unit / total_n,
    }
