"""Lookup-table machinery: product tables, codebooks, and mux-tree selection.

The paper's select logic is a binary tree of 2:1 muxes (15 of them for a
16-entry table).  The TPU-native analogue is a binary tree of ``jnp.where``
selects on the index bits — ``2**b - 1`` selects for a ``2**b``-entry table,
exactly the paper's mux count.  This is what makes the LUT *programmable*:
the same tree evaluates any codebook (uniform int4, NF4, arbitrary 16-value
tables), which is the beyond-paper generalization used by ``kernels.lut_gemm``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# The NF4 codebook (QLoRA, Dettmers et al. 2023) — a non-linear 16-entry LUT
# that the paper's mux-tree evaluates at identical hardware cost to uniform
# int4.  Demonstrates LUNA "programmability" beyond uniform quantization.
NF4_CODEBOOK = np.array([
    -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
    -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
    0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
    0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
    0.7229568362236023, 1.0,
], dtype=np.float32)


def product_table(w_codes: jax.Array, bits: int = 4) -> jax.Array:
    """Conventional-LUT product table (paper Fig 1): entry ``j = j * W``.

    Returns shape ``(2**bits, *w_codes.shape)`` int32.
    """
    idx = jnp.arange(1 << bits, dtype=jnp.int32)
    return idx.reshape((-1,) + (1,) * w_codes.ndim) * w_codes.astype(jnp.int32)[None]


def dc_table(w_codes: jax.Array, digit_bits: int = 2) -> jax.Array:
    """D&C sub-multiplier table {0, W, 2W, 3W} (paper Figs 2/3)."""
    return product_table(w_codes, digit_bits)


def mux_tree_select(table: jax.Array, idx: jax.Array) -> jax.Array:
    """Select ``table[idx]`` with a binary tree of 2:1 selects on idx bits.

    ``table``: ``(2**b, *S)`` where ``S`` broadcasts against ``idx.shape``.
    Uses ``2**b - 1`` vector selects — the paper's mux-tree, vectorized.
    Works under Pallas (no gather required).
    """
    n = table.shape[0]
    b = n.bit_length() - 1
    assert n == 1 << b, f"table size {n} not a power of two"
    level = table
    for bit in range(b):
        sel = ((idx >> bit) & 1).astype(bool)
        lo, hi = level[0::2], level[1::2]
        # broadcast sel against entry shape
        sel_b = jnp.broadcast_to(sel, jnp.broadcast_shapes(sel.shape, lo.shape[1:]))
        level = jnp.where(sel_b[None], hi, lo)
    return level[0]


def mux_count(table_size: int, out_bits: int) -> int:
    """Paper's 1-bit 2:1 mux count for a ``table_size``:1 mux of ``out_bits``."""
    return (table_size - 1) * out_bits


def codebook_dequant(codes: jax.Array, codebook: jax.Array) -> jax.Array:
    """Dequantize integer codes through an arbitrary codebook via mux tree."""
    return mux_tree_select(codebook.reshape(-1, *([1] * codes.ndim)), codes)


def dc_decompose_codebook(codebook: jax.Array, digit_bits: int = 2
                          ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Least-squares additive D&C split of a ``2**(2*digit_bits)``-entry LUT.

    The paper's Figs 2/3 decompose the 4-bit multiply LUT into two 2-bit
    sub-tables summed after selection: ``T[q] ~= HI[q >> digit_bits] +
    LO[q & (2**digit_bits - 1)]``.  For any *affine* codebook (uniform
    int4) the split is exact; for non-linear tables (NF4) this returns the
    least-squares-optimal additive pair (row/column means of the table
    viewed as a ``(2**digit_bits, 2**digit_bits)`` grid, grand mean folded
    into HI) plus the per-entry residual — the price of evaluating a
    programmable LUT with ``2 * (2**digit_bits - 1)`` muxes instead of
    ``2**(2*digit_bits) - 1`` (6 vs 15: the select tree behind the paper's
    ~3.7x area figure).

    Returns ``(hi_tab, lo_tab, residual)`` with ``hi_tab``/``lo_tab`` of
    shape ``(2**digit_bits,)`` and ``residual`` of ``codebook.shape``.
    """
    d = 1 << digit_bits
    grid = jnp.asarray(codebook, jnp.float32).reshape(d, d)  # [hi, lo]
    mean = jnp.mean(grid)
    hi_tab = jnp.mean(grid, axis=1)               # row means (grand mean kept)
    lo_tab = jnp.mean(grid, axis=0) - mean        # column means, centered
    residual = (grid - hi_tab[:, None] - lo_tab[None, :]).reshape(-1)
    return hi_tab, lo_tab, residual


def prune_residual(residual: jax.Array, threshold: float
                   ) -> tuple[jax.Array, jax.Array]:
    """Sparsify a D&C residual table: keep entries with ``|r| >= threshold``.

    The LUT-pruning tradeoff (PAPERS.md, Zhu et al.): residual entries
    below the threshold contribute less to reconstruction than they cost
    in table capacity, so they are dropped and only the kept set is
    stored.  Returns ``(kept_idx, kept_val)`` — int32 code indices and
    their residual values, the sparse representation a pruned sub-table
    stores (each kept entry costs one value plus a 1-byte code index
    instead of a dense slot for every code).
    """
    res = jnp.asarray(residual, jnp.float32)
    keep = np.flatnonzero(np.abs(np.asarray(res)) >= threshold)
    kept_idx = jnp.asarray(keep, jnp.int32)
    return kept_idx, res[kept_idx]


def scatter_residual(kept_idx: jax.Array, kept_val: jax.Array,
                     size: int = 16) -> jax.Array:
    """Densify a pruned residual for evaluation: dropped codes read 0.

    The sparse gather semantics of a pruned sub-table — a code either hits
    a kept entry or falls through to the pure ``HI + LO`` sum — expressed
    as one scatter into a zero table so the select tree stays uniform.
    """
    return jnp.zeros((size,), jnp.float32).at[kept_idx].set(kept_val)


def residual_table_bytes(n_kept: int, n_codes: int = 16,
                         value_bytes: int = 4, index_bytes: int = 1
                         ) -> tuple[int, int]:
    """(dense, pruned) storage bytes of a residual sub-table.

    Dense stores one value per code; the pruned form stores only the kept
    ``(index, value)`` pairs.  Used by the benches to report the capacity
    side of the LUT-pruning accuracy tradeoff.
    """
    dense = n_codes * value_bytes
    pruned = n_kept * (value_bytes + index_bytes)
    return dense, pruned
