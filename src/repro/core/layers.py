"""LunaDense — the paper's technique as a first-class, composable layer.

Every projection in every architecture routes through :func:`quant_matmul`,
so a single ``--quant`` flag turns any assigned architecture into a
LUNA-quantized model.  Modes:

  bf16              — no quantization (roofline baseline)
  int8              — symmetric int8 dynamic quantization (MXU int8 path)
  int4_dequant      — weight-only uniform int4, dequant then bf16 matmul
                      (the "conventional math" baseline the paper argues against)
  luna_conventional — full-LUT LUNA (exact; paper Fig 1 semantics)
  luna_dc           — exact D&C LUNA (paper Figs 2/3; optimized table)
  luna_approx       — ApproxD&C, Z_LSB := 0 (paper Fig 9)
  luna_approx2      — ApproxD&C2, Z_LSB := W (paper Fig 10)
  lut_nf4           — beyond-paper: NF4 codebook weights evaluated through the
                      paper's mux tree (programmable LUT)

Training uses the STE wrapper (forward = bit-exact integer path).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import lut
from repro.core.luna import LunaMode
from repro.core.quant import (QuantizedWeight, calibrate, dequantize,
                              quantize, ste_luna_matmul)

LUNA_MODE_OF = {
    "luna_conventional": LunaMode.CONVENTIONAL,
    "luna_dc": LunaMode.OPT_DC,
    "luna_approx": LunaMode.APPROX_DC,
    "luna_approx2": LunaMode.APPROX_DC2,
}

QUANT_MODES = ("bf16", "int8", "int4_dequant", "lut_nf4", *LUNA_MODE_OF)


@dataclass(frozen=True)
class QuantConfig:
    mode: str = "bf16"
    bits: int = 4
    use_pallas: bool = False   # route LUNA modes through the Pallas kernel
    # which projection groups to quantize (router/embeddings stay full-prec)
    targets: tuple = ("attn", "mlp", "moe")

    def __post_init__(self):
        if self.mode not in QUANT_MODES:
            raise ValueError(f"unknown quant mode {self.mode!r}; one of {QUANT_MODES}")

    def applies(self, group: str) -> bool:
        return self.mode != "bf16" and group in self.targets


def _int8_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    xq = calibrate(x, 8, axis=None, symmetric=True)
    wq = calibrate(w, 8, axis=-1, symmetric=True)
    qx = (quantize(x, xq) - xq.zero_point).astype(jnp.int8)
    qw = (quantize(w, wq) - wq.zero_point).astype(jnp.int8)
    acc = jax.lax.dot_general(qx, qw, (((x.ndim - 1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (xq.scale * wq.scale)


def _int4_dequant_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    wq = calibrate(w, 4, axis=-1)
    w_hat = dequantize(quantize(w, wq), wq).astype(x.dtype)
    return x @ w_hat


def _nf4_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """Weight-only NF4 through the mux tree (beyond-paper programmable LUT)."""
    absmax = jnp.maximum(jnp.max(jnp.abs(w), axis=0), 1e-8)     # per-channel
    w_norm = w / absmax
    cb = jnp.asarray(lut.NF4_CODEBOOK)
    # nearest codebook entry (quantize)
    codes = jnp.argmin(jnp.abs(w_norm[..., None] - cb), axis=-1).astype(jnp.int32)
    w_hat = lut.codebook_dequant(codes, cb) * absmax
    return x @ w_hat.astype(x.dtype)


def quant_matmul(x: jax.Array, w: jax.Array, cfg: QuantConfig | None,
                 group: str = "mlp") -> jax.Array:
    """``x @ w`` under the configured quantization mode.

    ``x``: (..., K); ``w``: (K, N).  Output dtype follows ``x``.

    ``w`` may also be a frozen :class:`~repro.core.quant.QuantizedWeight`
    (the engine's ``EngineConfig(quant=...)`` decode path substitutes them
    at construction); those route through the LUT GEMM selected by the
    container's static ``kernel`` tag — the affine D&C sub-table sum
    (``lut4``/``int4``) or the NF4 residual-corrected D&C / full-table
    paths (``nf4``/``nf4p``) — regardless of ``cfg``: the model-level
    ``cfg`` quantizes *dynamically* per call, engine-level quantization
    froze the weight once.
    """
    if isinstance(w, QuantizedWeight):
        from repro.kernels.lut_gemm import ops as lut_ops  # lazy: avoid cycle
        return lut_ops.quantized_matmul(x, w)
    if cfg is None or not cfg.applies(group):
        return x @ w
    if cfg.mode == "int8":
        return _int8_matmul(x, w).astype(x.dtype)
    if cfg.mode == "int4_dequant":
        return _int4_dequant_matmul(x, w)
    if cfg.mode == "lut_nf4":
        return _nf4_matmul(x, w)
    mode = LUNA_MODE_OF[cfg.mode]
    if cfg.use_pallas:
        from repro.kernels.luna_mm import ops as luna_ops  # lazy: avoid cycle
        return luna_ops.luna_matmul_f32_kernel(
            x.astype(jnp.float32), w.astype(jnp.float32), mode=mode.value,
            bits=cfg.bits).astype(x.dtype)
    return ste_luna_matmul(x.astype(jnp.float32), w.astype(jnp.float32),
                           mode.value, cfg.bits).astype(x.dtype)
