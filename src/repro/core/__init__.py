"""The paper's primary contribution: LUNA-CIM LUT-based D&C multiplication,
quantization substrate, hardware cost model, and the LunaDense layer."""
from repro.core.layers import QuantConfig, quant_matmul
from repro.core.luna import (LunaMode, combine_partials, luna_matmul,
                             luna_product, split_digits)
from repro.core.quant import QParams, calibrate, dequantize, quantize

__all__ = [
    "LunaMode", "luna_matmul", "luna_product", "combine_partials",
    "split_digits", "QuantConfig", "quant_matmul", "QParams", "calibrate",
    "dequantize", "quantize",
]
