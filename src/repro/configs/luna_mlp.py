"""The paper's own evaluation network (Section IV.A, Fig 13): a small MLP
whose matmuls run under each LUNA multiplier mode."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="luna-mlp", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    head_dim=16, mlp_type="gelu")
