"""Zamba2-1.2B [arXiv:2411.15242; hf]: Mamba2 backbone + shared attention
block every 6 layers, ssm_state=64."""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32000,
    head_dim=64, mlp_type="swiglu",
    ssm=SSMConfig(state_dim=64, expand=2, head_dim=64, num_groups=1,
                  conv_dim=4, chunk_size=256),
    hybrid=HybridConfig(period=6, shared_num_heads=32,
                        shared_num_kv_heads=32, shared_d_ff=8192))
