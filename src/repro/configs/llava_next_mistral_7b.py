"""LLaVA-NeXT (Mistral-7B backbone) [hf:llava-hf/llava-v1.6-mistral-7b-hf]:
anyres tiling STUBBED (input_specs provides precomputed patch embeddings)."""
from repro.configs.base import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=32000,
    head_dim=128, mlp_type="swiglu", rope_theta=1000000.0,
    vlm=VLMConfig(num_patches=576))
