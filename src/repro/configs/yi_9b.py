"""Yi-9B [arXiv:2403.04652; hf]: llama-arch, GQA kv=4, SwiGLU."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense", num_layers=48, d_model=4096,
    num_heads=32, num_kv_heads=4, d_ff=11008, vocab_size=64000,
    head_dim=128, mlp_type="swiglu")
