"""Mamba2-1.3B [arXiv:2405.21060]: attention-free SSD, ssm_state=128."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm", num_layers=48, d_model=2048,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=50280,
    head_dim=64, mlp_type="swiglu",
    ssm=SSMConfig(state_dim=128, expand=2, head_dim=64, num_groups=1,
                  conv_dim=4, chunk_size=256))
