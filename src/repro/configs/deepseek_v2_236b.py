"""DeepSeek-V2-236B [arXiv:2405.04434; hf]: MLA kv_lora=512 + q_lora=1536,
160 routed + 2 shared experts, top-6."""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
    num_heads=128, num_kv_heads=128, d_ff=1536, vocab_size=102400,
    head_dim=128, mlp_type="swiglu",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(num_experts=160, num_shared=2, top_k=6, d_expert=1536,
                  first_dense=1, dense_ff=12288))
