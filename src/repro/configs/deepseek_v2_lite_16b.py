"""DeepSeek-V2-Lite-16B [arXiv:2405.04434; hf]: MLA kv_lora=512,
64 routed + 2 shared experts, top-6.  (The assignment line's "160 routed"
is the 236B config; 64e matches the HF config — see DESIGN.md section 5.)"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", num_layers=27, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=102400,
    head_dim=128, mlp_type="swiglu",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_dim=128,
                  qk_rope_dim=64, v_dim=128),
    moe=MoEConfig(num_experts=64, num_shared=2, top_k=6, d_expert=1408,
                  first_dense=1, dense_ff=10944))
