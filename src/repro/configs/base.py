"""Config dataclasses for the model zoo and runtime."""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.layers import QuantConfig


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    num_shared: int = 0
    top_k: int = 2
    d_expert: int = 0           # expert FFN hidden size
    capacity_factor: float = 1.25
    first_dense: int = 1        # leading dense layers (deepseek-v2 style)
    dense_ff: int = 0           # FFN width of the dense layers
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0        # 0 = no q compression
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    expand: int = 2
    head_dim: int = 64
    num_groups: int = 1
    conv_dim: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: one weight-shared attention+MLP block applied every
    ``period`` SSM layers."""
    period: int = 6
    shared_num_heads: int = 32
    shared_num_kv_heads: int = 32
    shared_d_ff: int = 8192


@dataclass(frozen=True)
class EncDecConfig:
    enc_layers: int = 6
    enc_seq: int = 1500          # whisper: 30 s of audio @ 2x conv stride


@dataclass(frozen=True)
class VLMConfig:
    num_patches: int = 576       # llava-next base grid (anyres tiles stubbed)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | encdec | hybrid | ssm | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    mlp_type: str = "swiglu"     # swiglu | gelu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    hybrid: HybridConfig | None = None
    encdec: EncDecConfig | None = None
    vlm: VLMConfig | None = None
    quant: QuantConfig = field(default_factory=QuantConfig)
    attn_impl: str = "chunked"   # full | chunked | flash
    attn_chunk: int = 512
    remat: bool = True
    scan_layers: bool = True
    # decode attention: "dense" = plain cache update + SDPA (baseline);
    # "sharded" = flash-decode shard_map over the model axis (hillclimbed —
    # kills the cache-reshard collectives; see EXPERIMENTS.md §Perf)
    decode_attn: str = "dense"
    # attention operand precision: True (baseline) casts K/V/P to f32 and
    # materializes f32 copies; False keeps bf16 operands and relies on the
    # MXU's f32 accumulation (preferred_element_type) — hillclimb knob for
    # the HBM-bytes roofline term.
    attn_f32: bool = True
    # remat policy: "nothing" (full recompute, min memory) | "dots" (save
    # matmul outputs — trades memory for fewer recomputed FLOPs/bytes)
    remat_policy: str = "nothing"
    # serving param sharding: "fsdp" (baseline, same as training — weights
    # sharded over data+model, all-gathered per use) | "tp" (replicate over
    # data, shard over model only — no per-token weight all-gathers; right
    # when params_bf16/model_axis fits HBM)
    serve_param_sharding: str = "fsdp"
    # sharded flash-decode operand handling: "f32" (baseline) repeats KV to
    # full H in f32; "bf16_grouped" keeps bf16 operands and GQA-grouped
    # einsums (no repeat — legal inside shard_map where tensors are local)
    decode_attn_precision: str = "f32"
    # attention byte-efficiency knobs (hillclimb; False = paper-baseline):
    # fused scale+mask where() instead of mul + broadcast-bias add
    attn_fused_mask: bool = False
    # causal chunks attend only to keys <= chunk end (the flash kernel's
    # block skipping; halves causal attention work). Applies to the
    # unrolled/accounting path — the TPU runtime gets this from the kernel.
    attn_causal_skip: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family (see assignment)."""
        small = dict(
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
        )
        if self.moe:
            small["moe"] = replace(self.moe, num_experts=8, top_k=2,
                                   d_expert=64, dense_ff=256)
        if self.mla:
            small["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                                     qk_nope_dim=16, qk_rope_dim=16, v_dim=16)
        if self.ssm:
            small["ssm"] = replace(self.ssm, state_dim=16, head_dim=16,
                                   chunk_size=32)
        if self.hybrid:
            small["hybrid"] = replace(self.hybrid, period=2,
                                      shared_num_heads=4,
                                      shared_num_kv_heads=2, shared_d_ff=256)
            small["num_layers"] = 4
        if self.encdec:
            small["encdec"] = replace(self.encdec, enc_layers=2, enc_seq=64)
        if self.vlm:
            small["vlm"] = VLMConfig(num_patches=16)
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
