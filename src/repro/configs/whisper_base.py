"""Whisper-base [arXiv:2212.04356]: enc-dec; conv frontend STUBBED
(input_specs provides precomputed frame embeddings)."""
from repro.configs.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec", num_layers=6, d_model=512,
    num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865,
    head_dim=64, mlp_type="gelu",
    encdec=EncDecConfig(enc_layers=6, enc_seq=1500))
