"""Bench-regression gate: diff BENCH_engine.json against a committed
baseline and fail on throughput regressions.

Every numeric ``*_tok_s`` leaf of the two JSON trees is compared; a leaf
that drops more than ``--threshold`` (default 25%) below the baseline is a
regression and exits 1.  Leaves new in the current run are reported but
never fail (the baseline catches up at the next refresh); leaves MISSING
from the current run fail — a silently dropped scenario is how a gate goes
dark.  The ``prefix`` section additionally carries an ABSOLUTE gate: the
shared-system-prompt scenario's warm prefill tok/s must beat its own cold
prefill tok/s (a prefix cache that doesn't out-run recomputation is a
regression no baseline drift can excuse).  The ``quant`` section is gated
on presence: bf16/lut4/int4 decode rows must all report a positive tok/s
(the frozen-4-bit decode path must never silently drop out of the bench).
The ``sustained`` section (trace-driven load harness, virtual-time
deterministic) is gated absolutely too: present, goodput positive, and
high-priority p99 TTFT strictly below low-priority under overload.  The
``spec`` section (speculative decoding) is gated on presence, acceptance
in (0, 1], reconciled draft accounting, and a loose 0.2x collapse floor
on effective tok/s vs the non-speculative baseline.  The
``observability`` section is gated on recording overhead (tracing-on
decode tok/s >= 97% of tracing-off) and on trace/token consistency
(every emitted token is exactly one trace event, one submit + one finish
per request).
A markdown delta table is printed (append to ``$GITHUB_STEP_SUMMARY`` via
``--summary`` in CI).

Local repro / baseline refresh:

  PYTHONPATH=src python benchmarks/run.py --smoke      # writes BENCH_engine.json
  python benchmarks/compare.py                         # gate against baseline
  cp BENCH_engine.json BENCH_baseline.json             # refresh (commit it)
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_SUFFIX = "_tok_s"          # gate throughputs; occupancy etc. is FYI


def _leaves(tree, prefix=""):
    """Flatten a JSON tree to {dotted.path: number} for gated leaves."""
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_leaves(v, f"{prefix}.{k}" if prefix else str(k)))
    elif isinstance(tree, (int, float)) and prefix.endswith(GATED_SUFFIX):
        out[prefix] = float(tree)
    return out


def compare(baseline: dict, current: dict, threshold: float):
    """Returns (rows, regressions, missing): rows are
    (path, base, cur, delta_frac | None, status) sorted worst-first."""
    base = _leaves(baseline)
    cur = _leaves(current)
    rows, regressions, missing = [], [], []
    for path in sorted(set(base) | set(cur)):
        b, c = base.get(path), cur.get(path)
        if b is None:
            rows.append((path, None, c, None, "new"))
            continue
        if c is None:
            rows.append((path, b, None, None, "MISSING"))
            missing.append(path)
            continue
        delta = (c - b) / b if b > 0 else 0.0
        status = "ok"
        if delta < -threshold:
            status = "REGRESSION"
            regressions.append(path)
        rows.append((path, b, c, delta, status))
    rows.sort(key=lambda r: (r[3] is None, r[3] if r[3] is not None else 0.0))
    return rows, regressions, missing


def check_prefix_win(current: dict) -> list[str]:
    """Absolute warm-path gate on the ``prefix`` section: for every arch,
    warm prefill tok/s must be STRICTLY above cold.  Returns failure
    messages (empty = pass).  A current run without the section is caught
    by the MISSING-leaf rule once the baseline carries it."""
    fails = []
    for arch, row in current.get("prefix", {}).items():
        cold = row.get("cold_prefill_tok_s")
        warm = row.get("warm_prefill_tok_s")
        if cold is None or warm is None:
            fails.append(f"prefix.{arch}: cold/warm prefill tok/s missing")
        elif warm <= cold:
            fails.append(
                f"prefix.{arch}: warm prefill {warm:,.1f} tok/s does not "
                f"beat cold {cold:,.1f} tok/s")
    return fails


def check_latency_order(current: dict) -> list[str]:
    """Absolute request-lifecycle gate on the ``latency`` section: the
    priority scheduler must actually prioritize — high-priority p95 TTFT
    strictly below low-priority p95 TTFT under the mixed-load scenario.
    The section itself is required: a run without it silently dropped the
    scenario."""
    lat = current.get("latency")
    if not lat:
        return ["latency: section missing from the current run "
                "(priority_mixed_load scenario dropped?)"]
    hi, lo = lat.get("high"), lat.get("low")
    if not hi or not lo:
        return ["latency: high/low priority rows missing"]
    h, lw = hi.get("ttft_p95_s"), lo.get("ttft_p95_s")
    if h is None or lw is None:
        return ["latency: ttft_p95_s missing from high/low rows"]
    if h >= lw:
        return [f"latency: high-priority p95 TTFT {h * 1e3:,.1f} ms does "
                f"not beat low-priority {lw * 1e3:,.1f} ms"]
    return []


def check_quant_section(current: dict) -> list[str]:
    """Absolute presence gate on the ``quant`` section: the frozen-4-bit
    decode scenario must report a positive decode tok/s for every mode
    (bf16 baseline + affine lut4/int4 + non-affine nf4/nf4p).  CPU
    wall-clock ratios between modes are too noisy to gate; what must never
    happen silently is a quantized decode path dropping out of the bench.
    The pruned-residual row (nf4p) must additionally report its
    residual-table bytes saved (positive — pruning that saves nothing is a
    regression) and the bounded decode-weight MAE delta vs unpruned nf4."""
    q = current.get("quant")
    if not q:
        return ["quant: section missing from the current run "
                "(quant_decode_modes scenario dropped?)"]
    fails = []
    for mode in ("bf16", "lut4", "int4", "nf4", "nf4p"):
        row = q.get(mode)
        tok_s = row.get("decode_tok_s") if isinstance(row, dict) else None
        if tok_s is None:
            fails.append(f"quant.{mode}: decode_tok_s missing")
        elif tok_s <= 0:
            fails.append(f"quant.{mode}: decode_tok_s {tok_s} not positive")
    nf4p = q.get("nf4p")
    if isinstance(nf4p, dict):
        saved = nf4p.get("table_bytes_saved")
        if saved is None:
            fails.append("quant.nf4p: table_bytes_saved missing")
        elif saved <= 0:
            fails.append(f"quant.nf4p: table_bytes_saved {saved} "
                         "not positive (pruning saved nothing)")
        mae = nf4p.get("mae_delta")
        if mae is None:
            fails.append("quant.nf4p: mae_delta missing")
        elif not mae >= 0:
            fails.append(f"quant.nf4p: mae_delta {mae} invalid")
    return fails


def check_sustained_section(current: dict) -> list[str]:
    """Absolute gate on the ``sustained`` section (trace-driven load
    harness, deterministic virtual-time runs): the section must be
    present, every arch must report positive goodput, and under overload
    the priority scheduler must hold the latency split — high-priority
    (class 1) p99 TTFT strictly below low-priority (class 0).  These
    numbers come from a virtual clock, so any change is a real behavior
    change, not timing noise."""
    sus = current.get("sustained")
    if not sus:
        return ["sustained: section missing from the current run "
                "(load-harness scenario dropped?)"]
    fails = []
    for arch, rep in sus.items():
        good = rep.get("goodput_tok_s")
        if good is None or good <= 0:
            fails.append(f"sustained.{arch}: goodput_tok_s {good} "
                         "not positive")
        byp = rep.get("by_priority", {})
        hi = (byp.get("1", {}).get("ttft") or {}).get("p99_s")
        lo = (byp.get("0", {}).get("ttft") or {}).get("p99_s")
        if hi is None or lo is None:
            fails.append(f"sustained.{arch}: per-priority ttft p99 missing")
        elif hi >= lo:
            fails.append(
                f"sustained.{arch}: high-priority p99 TTFT {hi * 1e3:,.1f} "
                f"ms does not beat low-priority {lo * 1e3:,.1f} ms under "
                "overload")
    return fails


def check_spec_section(current: dict) -> list[str]:
    """Absolute gate on the ``spec`` section (speculative decoding):
    the baseline row and both proposer rows (ngram / self_lut) must be
    present, acceptance must be a real rate in (0, 1], draft accounting
    must reconcile (0 <= accepted <= drafted, drafted > 0), and
    effective decode tok/s must clear a LOOSE floor vs the
    non-speculative baseline (>= 0.2x).  The floor is a collapse guard,
    not a speedup claim: on CPU the self-speculative drafter pays
    ``spec_k`` extra sequential decode steps per tick, so only
    high-acceptance workloads net out ahead — what must never happen
    silently is the spec path grinding to a halt, or acceptance going to
    zero (drafts never matching the verifier means the proposer or the
    accept scan broke, since the bench prompts are periodic by
    construction)."""
    spec = current.get("spec")
    if not spec:
        return ["spec: section missing from the current run "
                "(speculative_decode scenario dropped?)"]
    fails = []
    base = (spec.get("baseline") or {}).get("decode_tok_s")
    if base is None or base <= 0:
        fails.append(f"spec.baseline: decode_tok_s {base} not positive")
    for mode in ("ngram", "self_lut"):
        row = spec.get(mode)
        if not isinstance(row, dict):
            fails.append(f"spec.{mode}: row missing")
            continue
        tok_s = row.get("decode_tok_s")
        if tok_s is None or tok_s <= 0:
            fails.append(f"spec.{mode}: decode_tok_s {tok_s} not positive")
        acc = row.get("acceptance")
        if acc is None or not 0.0 < acc <= 1.0:
            fails.append(f"spec.{mode}: acceptance {acc} outside (0, 1]")
        drafted, accepted = row.get("drafted"), row.get("accepted")
        if not drafted or accepted is None \
                or not 0 <= accepted <= drafted:
            fails.append(f"spec.{mode}: draft accounting drafted={drafted} "
                         f"accepted={accepted} inconsistent")
        ratio = row.get("tok_s_vs_baseline")
        if ratio is None:
            fails.append(f"spec.{mode}: tok_s_vs_baseline missing")
        elif ratio < 0.2:
            fails.append(
                f"spec.{mode}: effective decode {ratio:.2f}x baseline — "
                "below the 0.2x collapse floor")
    return fails


def check_observability_section(current: dict) -> list[str]:
    """Absolute gate on the ``observability`` section: the section must be
    present, recording overhead must be bounded (tracing-on decode tok/s
    at least 97% of tracing-off — median per-tick time over interleaved
    off/on windows, so a miss is a real hot-path cost, not a scheduler
    hiccup), and the traced consistency
    run's event counts must reconcile with its token counts: every emitted
    token is exactly one first_token or token event, and every request has
    exactly one submit and one finish event."""
    obs = current.get("observability")
    if not obs:
        return ["observability: section missing from the current run "
                "(observability_overhead scenario dropped?)"]
    fails = []
    ratio = obs.get("overhead_ratio")
    if ratio is None:
        fails.append("observability: overhead_ratio missing")
    elif ratio < 0.97:
        fails.append(
            f"observability: tracing-on decode is {ratio:.1%} of "
            "tracing-off — recording overhead exceeds the 3% budget")
    tr = obs.get("trace")
    if not isinstance(tr, dict):
        return fails + ["observability: trace consistency counts missing"]
    emitted = tr.get("emitted_tokens")
    tok_ev = tr.get("first_token_events", 0) + tr.get("token_events", 0)
    if emitted is None or emitted <= 0:
        fails.append(f"observability: emitted_tokens {emitted} not positive")
    elif tok_ev != emitted:
        fails.append(
            f"observability: {tok_ev} first_token+token events != "
            f"{emitted} emitted tokens")
    n = tr.get("requests")
    for ev in ("submit_events", "finish_events"):
        if tr.get(ev) != n:
            fails.append(f"observability: {ev} {tr.get(ev)} != "
                         f"{n} requests")
    if tr.get("dropped", 0) != 0:
        fails.append(f"observability: consistency run dropped "
                     f"{tr['dropped']} events (ring buffer too small "
                     "for the scenario)")
    return fails


def markdown_table(rows, threshold: float) -> str:
    def fmt(v):
        return "—" if v is None else f"{v:,.1f}"

    lines = [f"### Bench regression gate (fail < -{threshold:.0%})", "",
             "| metric | baseline tok/s | current tok/s | delta | status |",
             "|---|---:|---:|---:|---|"]
    for path, b, c, delta, status in rows:
        d = "—" if delta is None else f"{delta:+.1%}"
        mark = {"REGRESSION": "❌", "MISSING": "❌", "new": "🆕"}.get(
            status, "✅")
        lines.append(f"| `{path}` | {fmt(b)} | {fmt(c)} | {d} "
                     f"| {mark} {status} |")
    return "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_engine.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated fractional drop (0.25 = 25%%)")
    ap.add_argument("--summary", default=None,
                    help="file to append the markdown table to "
                    "(e.g. $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)

    rows, regressions, missing = compare(baseline, current, args.threshold)
    prefix_fails = check_prefix_win(current)
    latency_fails = check_latency_order(current)
    quant_fails = check_quant_section(current)
    sustained_fails = check_sustained_section(current)
    spec_fails = check_spec_section(current)
    obs_fails = check_observability_section(current)
    abs_fails = (prefix_fails + latency_fails + quant_fails
                 + sustained_fails + spec_fails + obs_fails)
    table = markdown_table(rows, args.threshold)
    if abs_fails:
        table += "\n" + "\n".join(f"❌ {m}" for m in abs_fails) + "\n"
    else:
        if current.get("prefix"):
            wins = ", ".join(f"{a} {r['speedup']:.2f}x"
                             for a, r in current["prefix"].items()
                             if "speedup" in r)
            table += f"\n✅ prefix warm-path win: {wins}\n"
        lat = current.get("latency", {})
        if lat:
            table += (f"✅ priority split: high p95 TTFT "
                      f"{lat['high']['ttft_p95_s'] * 1e3:.1f} ms < low "
                      f"{lat['low']['ttft_p95_s'] * 1e3:.1f} ms\n")
        q = current.get("quant", {})
        if q:
            modes = ", ".join(f"{m} {r['decode_tok_s']:.1f}"
                              for m, r in q.items()
                              if isinstance(r, dict)
                              and "decode_tok_s" in r)
            table += f"✅ quant decode tok/s: {modes}\n"
        sus = current.get("sustained", {})
        if sus:
            parts = ", ".join(
                f"{a} {r['goodput_tok_s']:.0f} tok/s "
                f"(miss {r['deadline_miss_rate']:.0%})"
                for a, r in sus.items())
            table += f"✅ sustained goodput: {parts}\n"
        sp = current.get("spec", {})
        if sp:
            parts = ", ".join(
                f"{m} {r['tok_s_vs_baseline']:.2f}x "
                f"(acc {r['acceptance']:.0%})"
                for m, r in sp.items() if "acceptance" in r)
            table += f"✅ speculative decode vs baseline: {parts}\n"
        obs = current.get("observability", {})
        if obs:
            table += (f"✅ observability: tracing overhead "
                      f"{obs['overhead_ratio']:.1%} of baseline tok/s, "
                      f"{obs['trace']['events_total']} trace events "
                      "reconciled\n")
    print(table)
    if args.summary:
        with open(args.summary, "a") as f:
            f.write(table)

    if regressions or missing or abs_fails:
        for p in regressions:
            print(f"FAIL: {p} regressed more than {args.threshold:.0%}",
                  file=sys.stderr)
        for p in missing:
            print(f"FAIL: {p} missing from the current run", file=sys.stderr)
        for m in abs_fails:
            print(f"FAIL: {m}", file=sys.stderr)
        sys.exit(1)
    print(f"gate OK: {sum(1 for r in rows if r[4] == 'ok')} metrics within "
          f"{args.threshold:.0%} of baseline")


if __name__ == "__main__":
    main()
