"""Serving-engine benchmarks: decode throughput vs slab width, batched
(bucketed) prefill vs per-row prefill, paged-block KV vs the dense slab,
and chunked-prefill interleave under a long-prompt admission — for the
attention AND recurrent (ssm/hybrid, state-continuing SSD scan) families.

Prints the orchestrator's ``name,us_per_call,derived`` CSV rows.  Timings on
CPU are correctness-level; the derived column carries the quantities that
transfer (tokens/s, per-token cost, speedup ratios).

  PYTHONPATH=src python benchmarks/engine_bench.py --quant luna_approx
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
for _p in (_SRC, _ROOT):
    if _p not in sys.path:
        sys.path.insert(0, _p)

DEF_BATCHES = (1, 8, 32)


def _build(quant: str, max_batch: int, max_seq: int, arch: str = "yi-9b",
           clock=None, **engine_kw):
    """``quant`` routes like the CLIs: ``lut4``/``int4`` become
    ``EngineConfig.quant`` (frozen 4-bit decode weights through the D&C LUT
    gemm); any other non-bf16 spelling is a model-level ``QuantConfig``
    mode (dynamic, every projection)."""
    import jax

    from repro.core.layers import QuantConfig
    from repro.models.registry import get_config, get_model
    from repro.serve.config import ENGINE_QUANT_MODES, EngineConfig
    from repro.serve.engine import Engine

    cfg = get_config(arch).reduced()
    if quant in ENGINE_QUANT_MODES:
        engine_kw["quant"] = quant
    elif quant != "bf16":
        from dataclasses import replace
        cfg = replace(cfg, quant=QuantConfig(mode=quant))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    econf = EngineConfig(max_batch=max_batch, max_seq=max_seq, **engine_kw)
    return cfg, Engine(cfg, params, econf, clock=clock)


def _steady_decode_tok_s(eng, cfg, mb: int, ticks: int, max_seq: int,
                         periodic: bool = False) -> float:
    """Fill every slot, burn warm-up (compile) ticks, time ``ticks``.
    ``periodic``: repeat a short token pattern instead of a uniform random
    prompt — gives the n-gram draft proposer material (the spec section
    runs its baseline with the same prompts for a fair ratio)."""
    import numpy as np

    from repro.serve.engine import Request

    rng = np.random.default_rng(0)

    def prompt():
        if periodic:
            return rng.integers(1, cfg.vocab_size, 3).tolist() * 3
        return rng.integers(1, cfg.vocab_size, 6).tolist()

    reqs = [Request(rid=i, prompt=prompt(),
                    max_new=max_seq)           # never finishes mid-bench
            for i in range(mb)]
    for i, r in enumerate(reqs):
        assert eng.submit(r), i
    for _ in range(3):                          # warm-up (compile) ticks
        eng.step()
    eng.metrics.decode_s = 0.0
    eng.metrics.decode_tokens = 0
    t0 = time.perf_counter()
    for _ in range(ticks):
        eng.step()
    wall = time.perf_counter() - t0
    return eng.metrics.decode_tokens / max(wall, 1e-9)


def decode_throughput(quant: str = "bf16", batches=DEF_BATCHES,
                      ticks: int = 24, max_seq: int = 128) -> dict:
    """Steady-state decode tokens/s with every slot occupied, per slab
    width."""
    rows = {}
    for mb in batches:
        cfg, eng = _build(quant, mb, max_seq)
        tok_s = _steady_decode_tok_s(eng, cfg, mb, ticks, max_seq)
        us = mb / max(tok_s, 1e-9) * 1e6
        rows[mb] = tok_s
        print(f"engine_decode_b{mb},{us:.0f},"
              f"tok_s={tok_s:.1f};quant={quant};ticks={ticks}")
    if 1 in rows:
        for mb in batches:
            if mb != 1:
                print(f"engine_decode_scaling_b{mb},0,"
                      f"tok_s_ratio_vs_b1={rows[mb] / rows[1]:.2f}")
    return rows


def decode_paged_vs_dense(quant: str = "bf16", batch: int = 8,
                          ticks: int = 24, max_seq: int = 128) -> dict:
    """Steady-state decode: paged-block pool vs the dense slab, same
    workload (acceptance gate: the paged gather must not regress decode)."""
    rows = {}
    for mode, kw in (("dense", {}),
                     ("paged", {"paged": True, "block_size": 16})):
        cfg, eng = _build(quant, batch, max_seq, **kw)
        tok_s = _steady_decode_tok_s(eng, cfg, batch, ticks, max_seq)
        us = batch / max(tok_s, 1e-9) * 1e6
        rows[mode] = tok_s
        print(f"engine_decode_{mode}_b{batch},{us:.0f},"
              f"tok_s={tok_s:.1f};quant={quant}")
    ratio = rows["paged"] / max(rows["dense"], 1e-9)
    print(f"engine_decode_paged_vs_dense_b{batch},0,"
          f"tok_s_ratio={ratio:.2f}")
    return {"dense": rows["dense"], "paged": rows["paged"], "ratio": ratio}


def quant_decode_modes(batch: int = 4, ticks: int = 12, max_seq: int = 64,
                       modes=("bf16", "lut4", "int4", "nf4", "nf4p")) -> dict:
    """Steady-state decode tok/s per weight-quantization mode, same
    scenario (the ``quant`` section of ``BENCH_engine.json``).

    ``bf16`` is the dense baseline; ``lut4`` evaluates frozen 4-bit codes
    through the D&C sub-table LUT gemm; ``int4`` direct-dequants the same
    codes (identical tokens, conventional evaluation); ``nf4`` encodes
    against the non-affine NF4 codebook and adds the least-squares
    residual correction to the 6-select sum; ``nf4p`` prunes that residual
    sub-table (its row also reports the residual table bytes saved and the
    decode-weight MAE delta vs unpruned nf4).  Decode is memory-bound on
    real accelerators, so 4-bit weights approach a direct tok/s win there;
    CPU-interpreted numbers only track relative shape.
    """
    rows = {}
    for mode in modes:
        cfg, eng = _build(mode, batch, max_seq)
        tok_s = _steady_decode_tok_s(eng, cfg, batch, ticks, max_seq)
        rows[mode] = {"decode_tok_s": tok_s}
        print(f"engine_quant_{mode}_b{batch},{batch / max(tok_s, 1e-9) * 1e6:.0f},"
              f"tok_s={tok_s:.1f};ticks={ticks}")
    for mode in modes[1:]:
        ratio = rows[mode]["decode_tok_s"] / max(
            rows["bf16"]["decode_tok_s"], 1e-9)
        print(f"engine_quant_{mode}_vs_bf16,0,tok_s_ratio={ratio:.2f}")
    if "nf4p" in rows:
        rows["nf4p"].update(_nf4p_prune_stats())
        print(f"engine_quant_nf4p_residual_table,0,"
              f"bytes_saved={rows['nf4p']['table_bytes_saved']};"
              f"mae_delta={rows['nf4p']['mae_delta']:.4f}")
    return rows


def speculative_decode(batch: int = 4, ticks: int = 12, max_seq: int = 64,
                       spec_k: int = 4) -> dict:
    """Steady-state decode tok/s with speculative decoding vs the plain
    tick, same scenario (the ``spec`` section of ``BENCH_engine.json``).

    One row per proposer (``ngram`` prompt-lookup, ``self_lut``
    self-speculation over the pruned-LUT nf4p tree) plus the non-spec
    ``baseline``; each row reports emitted tok/s, the draft acceptance
    rate from the engine's own counters, and the ratio vs baseline.
    Prompts are periodic so prompt-lookup has material.  On a real
    accelerator the verify window amortizes weight reads over ``spec_k+1``
    positions and accepted drafts are nearly free; CPU-interpreted ratios
    only show the relative shape (``compare.check_spec_section`` gates
    presence, acceptance sanity, and a loose tok/s floor, not a CPU
    speedup)."""
    rows = {}
    cfg, eng = _build("bf16", batch, max_seq)
    base = _steady_decode_tok_s(eng, cfg, batch, ticks, max_seq,
                                periodic=True)
    rows["baseline"] = {"decode_tok_s": base}
    print(f"engine_spec_baseline_b{batch},"
          f"{batch / max(base, 1e-9) * 1e6:.0f},tok_s={base:.1f}")
    for mode in ("ngram", "self_lut"):
        cfg, eng = _build("bf16", batch, max_seq, spec=mode, spec_k=spec_k)
        tok_s = _steady_decode_tok_s(eng, cfg, batch, ticks, max_seq,
                                     periodic=True)
        m = eng.metrics
        drafted, accepted = int(m.spec_drafted), int(m.spec_accepted)
        acc = accepted / drafted if drafted else 0.0
        ratio = tok_s / max(base, 1e-9)
        rows[mode] = {"decode_tok_s": tok_s, "acceptance": acc,
                      "drafted": drafted, "accepted": accepted,
                      "tok_s_vs_baseline": ratio}
        print(f"engine_spec_{mode}_b{batch},"
              f"{batch / max(tok_s, 1e-9) * 1e6:.0f},tok_s={tok_s:.1f};"
              f"acceptance={acc:.2f};vs_baseline={ratio:.2f}")
    return rows


def _nf4p_prune_stats() -> dict:
    """Residual-table bytes saved by pruning, and the decode-weight MAE
    delta it costs vs the unpruned nf4 reconstruction (gated by
    ``compare.check_quant_section``)."""
    import jax
    import jax.numpy as jnp

    from repro.core.lut import (NF4_CODEBOOK, dc_decompose_codebook,
                                prune_residual, residual_table_bytes)
    from repro.core.quant import NF4P_PRUNE_THRESHOLD, quantize_weight
    from repro.kernels.lut_gemm.ops import quantized_matmul

    _, _, residual = dc_decompose_codebook(jnp.asarray(NF4_CODEBOOK))
    kept_idx, _ = prune_residual(residual, NF4P_PRUNE_THRESHOLD)
    dense, pruned = residual_table_bytes(int(kept_idx.shape[0]))
    w = jax.random.normal(jax.random.PRNGKey(7), (128, 64), jnp.float32)
    eye = jnp.eye(w.shape[0], dtype=jnp.float32)   # W_hat = I @ W_hat
    w_nf4 = quantized_matmul(eye, quantize_weight(w, "nf4_dc"))
    w_nf4p = quantized_matmul(
        eye, quantize_weight(w, "nf4_dc", NF4P_PRUNE_THRESHOLD))
    mae_delta = float(jnp.abs(w_nf4p - w_nf4).mean())
    return {"table_bytes_saved": dense - pruned,
            "residual_kept": int(kept_idx.shape[0]),
            "mae_delta": mae_delta}


def prefill_batched_vs_per_row(quant: str = "bf16", batch: int = 8,
                               prompt_len: int = 24, max_seq: int = 128,
                               iters: int = 3) -> dict:
    """One bucketed prefill call + slab scatter vs per-row prefill calls.

    Same prompts, same slab; per-row mode submits each request alone (the
    seed engine's strategy), batched mode admits them as one bucket.
    """
    import numpy as np

    from repro.serve.engine import Request

    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, 500, prompt_len).tolist()
               for _ in range(batch)]

    def _run(batched: bool) -> float:
        cfg, eng = _build(quant, batch, max_seq)
        vocab = cfg.vocab_size
        ps = [[t % vocab for t in p] for p in prompts]
        best = float("inf")
        for it in range(iters + 1):             # iter 0 = compile warm-up
            eng.slots = [None] * batch
            eng.active.clear()
            t0 = time.perf_counter()
            if batched:
                reqs = [Request(rid=it * batch + i, prompt=p, max_new=4)
                        for i, p in enumerate(ps)]
                eng._admit(reqs, list(range(batch)))
            else:
                for i, p in enumerate(ps):
                    assert eng.submit(
                        Request(rid=it * batch + i, prompt=p, max_new=4))
            wall = time.perf_counter() - t0
            if it > 0:
                best = min(best, wall)
        return best

    per_row = _run(batched=False)
    batched = _run(batched=True)
    speedup = per_row / max(batched, 1e-9)
    print(f"engine_prefill_per_row_b{batch},{per_row * 1e6:.0f},"
          f"len={prompt_len};quant={quant}")
    print(f"engine_prefill_batched_b{batch},{batched * 1e6:.0f},"
          f"speedup_vs_per_row={speedup:.2f}")
    return {"per_row_s": per_row, "batched_s": batched, "speedup": speedup}


def prefix_shared_system_prompt(quant: str = "bf16", n_requests: int = 6,
                                head_len: int = 64, tail_len: int = 8,
                                max_seq: int = 96) -> dict:
    """The million-user traffic shape: every request opens with the same
    system-prompt head.  Cold = every admission prefills from token 0;
    warm = the prefix cache seeds the head (transformer: copy-on-write
    paged blocks; mamba2: dense state snapshot) and prefills only the
    tail.  Reported tok/s counts the FULL prompt (reused + recomputed)
    over prefill wall-clock — the effective admission throughput.

    Acceptance gate (``benchmarks/compare.py``): warm strictly above cold.
    """
    import numpy as np

    from repro.serve.engine import Request

    out = {}
    for arch, kw in (("yi-9b", {"paged": True, "block_size": 16}),
                     ("mamba2-1.3b", {})):
        cfg, cold_eng = _build(quant, 4, max_seq, arch=arch, **kw)
        _, warm_eng = _build(quant, 4, max_seq, arch=arch,
                             prefix_cache=True, **kw)
        rng = np.random.default_rng(5)
        head = rng.integers(1, cfg.vocab_size, head_len).tolist()
        prompts = [head + rng.integers(1, cfg.vocab_size, tail_len).tolist()
                   for _ in range(n_requests)]
        # compile warm-up on a DIFFERENT head: both engines' prefill
        # programs (bucketed; staged seed + finish) get built off the clock
        wu_head = rng.integers(1, cfg.vocab_size, head_len).tolist()
        for eng in (cold_eng, warm_eng):
            for i in range(2):
                tail = rng.integers(1, cfg.vocab_size, tail_len).tolist()
                assert eng.serve([Request(rid=900 + i, prompt=wu_head + tail,
                                          max_new=1)])["done"]

        def run(eng, ps, rid0):
            tok = wall = 0.0
            hits = reused = 0
            for i, p in enumerate(ps):
                stats = eng.serve([Request(rid=rid0 + i, prompt=p,
                                           max_new=1)])
                assert stats["done"]
                wall += stats["prefill_s"]
                tok += stats["prefill_tokens"] + stats["prefix_tokens_reused"]
                hits += stats["prefix_hits"]
                reused += stats["prefix_tokens_reused"]
            return tok / max(wall, 1e-9), hits, reused

        cold_tok_s, _, _ = run(cold_eng, prompts, 0)
        # first warm-engine request populates the tree (not measured) ...
        assert warm_eng.serve([Request(rid=50, prompt=prompts[0],
                                       max_new=1)])["done"]
        # ... every following one must hit the shared head
        warm_tok_s, hits, reused = run(warm_eng, prompts[1:], 51)
        assert hits == n_requests - 1, (arch, hits)
        speedup = warm_tok_s / max(cold_tok_s, 1e-9)
        out[arch] = {"cold_prefill_tok_s": cold_tok_s,
                     "warm_prefill_tok_s": warm_tok_s,
                     "speedup": speedup,
                     "prefix_hits": hits,
                     "tokens_reused": reused}
        print(f"engine_prefix_{arch}_cold,0,prefill_tok_s={cold_tok_s:.1f};"
              f"head={head_len};quant={quant}")
        print(f"engine_prefix_{arch}_warm,0,prefill_tok_s={warm_tok_s:.1f};"
              f"speedup_vs_cold={speedup:.2f};reused={reused}")
    return out


def priority_mixed_load(quant: str = "bf16", n_each: int = 6,
                        max_seq: int = 64, max_new: int = 8,
                        max_batch: int = 2) -> dict:
    """Request-lifecycle latency under a mixed priority workload: 2*n_each
    requests (interleaved high/low priority at submission) contend for
    ``max_batch`` slots; the scheduler admits priority classes first, so
    high-priority requests should see strictly lower tail TTFT.

    Reports per-class TTFT and ITL p50/p95 (seconds) for the ``latency``
    section of ``BENCH_engine.json``.  Acceptance gate
    (``benchmarks/compare.py``): high-priority p95 TTFT < low-priority
    p95 TTFT.
    """
    import numpy as np

    from repro.serve.engine import Request

    cfg, eng = _build(quant, max_batch, max_seq)
    rng = np.random.default_rng(3)
    # compile warm-up off the clock: one bucketed prefill + decode program
    wu = [Request(rid=900 + i,
                  prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                  max_new=2)
          for i in range(max_batch)]
    assert eng.serve(wu)["done"]

    reqs = []
    for i in range(2 * n_each):
        pri = 1 if i % 2 == 0 else 0          # interleaved arrival order
        reqs.append(Request(
            rid=i, prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
            max_new=max_new, priority=pri))
    stats = eng.serve(reqs)
    assert stats["done"]

    out = {}
    for name, pri in (("high", 1), ("low", 0)):
        sel = [r for r in reqs if r.priority == pri]
        ttft = np.asarray([r.token_ts[0] - r.submit_ts for r in sel])
        itl = np.concatenate([np.diff(np.asarray(r.token_ts))
                              for r in sel if len(r.token_ts) > 1])
        out[name] = {
            "n": len(sel),
            "ttft_p50_s": float(np.percentile(ttft, 50)),
            "ttft_p95_s": float(np.percentile(ttft, 95)),
            "itl_p50_s": float(np.percentile(itl, 50)),
            "itl_p95_s": float(np.percentile(itl, 95)),
        }
        print(f"engine_latency_{name},0,"
              f"ttft_p50_ms={out[name]['ttft_p50_s'] * 1e3:.1f};"
              f"ttft_p95_ms={out[name]['ttft_p95_s'] * 1e3:.1f};"
              f"itl_p50_ms={out[name]['itl_p50_s'] * 1e3:.1f};"
              f"itl_p95_ms={out[name]['itl_p95_s'] * 1e3:.1f};quant={quant}")
    ratio = out["high"]["ttft_p95_s"] / max(out["low"]["ttft_p95_s"], 1e-9)
    print(f"engine_latency_priority_split,0,"
          f"high_vs_low_p95_ttft_ratio={ratio:.2f}")
    return out


def _admit_long_interleave(quant: str, max_seq: int, chunk: int, arch: str,
                           modes, tag: str = "") -> dict:
    """Shared harness: 3 short requests decode while one (max_seq-1)-token
    prompt is admitted; reports decode tokens emitted during the admission
    window per mode (whole-prompt admission stalls every decoder for the
    full prefill; chunked admission interleaves one chunk per tick)."""
    import numpy as np

    from repro.serve.engine import Request

    rows = {}
    for mode, kw in modes:
        cfg, eng = _build(quant, 4, max_seq, arch=arch, **kw)
        rng = np.random.default_rng(0)
        short = [Request(rid=i,
                         prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                         max_new=max_seq)
                 for i in range(3)]
        for r in short:
            assert eng.submit(r)
        for _ in range(3):                      # warm-up/compile ticks
            eng.step()
        long = Request(rid=9,
                       prompt=rng.integers(1, cfg.vocab_size,
                                           max_seq - 1).tolist(),
                       max_new=4)
        emitted0 = sum(len(r.out) for r in short)
        t0 = time.perf_counter()
        assert eng.submit(long)                 # whole mode prefills HERE
        while not long.out:                     # chunked mode: tick it in
            eng.step()
        wall = time.perf_counter() - t0
        during = sum(len(r.out) for r in short) - emitted0
        rows[mode] = during
        print(f"engine_admit_long_{tag}{mode},{wall * 1e6:.0f},"
              f"decode_toks_during_admission={during};len={max_seq - 1};"
              f"chunk={0 if mode == 'whole' else chunk}")
    return rows


def long_prompt_interleave(quant: str = "bf16", max_seq: int = 128,
                           chunk: int = 16) -> dict:
    """Attention-family long-admission interleave (yi-9b): whole vs
    chunked prefill."""
    return _admit_long_interleave(
        quant, max_seq, chunk, "yi-9b",
        [("whole", {}), ("chunked", {"prefill_chunk": chunk})])


def recurrent_long_prompt_interleave(quant: str = "bf16", max_seq: int = 64,
                                     chunk: int = 16,
                                     archs=("mamba2-1.3b", "zamba2-1.2b")
                                     ) -> dict:
    """The recurrent-family spelling of :func:`long_prompt_interleave`:
    chunked admission resumes the state-continuing SSD scan one chunk per
    tick; the hybrid additionally runs its attention leaves in the paged
    block pool (split substrate)."""
    out = {}
    for arch in archs:
        modes = [("whole", {}), ("chunked", {"prefill_chunk": chunk})]
        if arch == "zamba2-1.2b":
            modes.append(("paged_chunked",
                          {"prefill_chunk": chunk, "paged": True,
                           "block_size": 16}))
        out[arch] = _admit_long_interleave(quant, max_seq, chunk, arch,
                                           modes, tag=f"{arch}_")
    return out


def observability_overhead(quant: str = "bf16", batch: int = 4,
                           ticks: int = 30, repeats: int = 5,
                           max_seq: int = 512,
                           trace_path: str | None = None,
                           metrics_path: str | None = None) -> dict:
    """Recording overhead + trace consistency: the ``observability``
    section of ``BENCH_engine.json``.

    Overhead: ONE engine, slots filled with never-finishing requests,
    decode tok/s measured with the tracer toggled off/on in interleaved
    repeats (same compiled programs, same thermal window — tok/s is
    computed from the MEDIAN per-tick wall time over all repeats, so a
    multi-ms scheduler hiccup inside one window can't bias a mode, and
    the off/on order flips every repeat so monotonic frequency drift
    can't either).  The registry
    observations are always on; the delta isolates trace-event
    recording.  Gate (``compare.check_observability_section``): on/off
    ratio >= 0.97.

    Consistency: a second engine on a virtual clock serves a small mix
    with tracing on; event counts must reconcile with token counts
    (first_token + token events == tokens emitted, one submit and one
    finish per request).  Optionally dumps that run's Perfetto trace and
    Prometheus text to ``trace_path`` / ``metrics_path`` (CI artifacts).
    """
    import numpy as np

    from repro.serve.engine import Request

    cfg, eng = _build(quant, batch, max_seq)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                    max_new=max_seq)           # never finishes mid-bench
            for i in range(batch)]
    for i, r in enumerate(reqs):
        assert eng.submit(r), i
    for _ in range(3):                          # warm-up (compile) ticks
        eng.step()

    def measure() -> list[float]:
        out = []
        for _ in range(ticks):
            t0 = time.perf_counter()
            eng.step()
            out.append(time.perf_counter() - t0)
        return out

    # per-TICK samples, pooled across alternating off/on windows: the
    # median over repeats*ticks samples shrugs off multi-ms scheduler
    # hiccups that bias any whole-window estimator (best-of included)
    samples = {"off": [], "on": []}
    for rep in range(repeats):
        order = ("off", "on") if rep % 2 == 0 else ("on", "off")
        for mode in order:
            eng.tracer.enabled = mode == "on"
            samples[mode].extend(measure())
    eng.tracer.enabled = False

    def tok_s(mode: str) -> float:
        ts = sorted(samples[mode])
        return batch / max(ts[len(ts) // 2], 1e-9)   # median tick time

    best = {m: tok_s(m) for m in ("off", "on")}
    ratio = best["on"] / max(best["off"], 1e-9)
    print(f"engine_obs_overhead_b{batch},0,"
          f"decode_tok_s_off={best['off']:.1f};"
          f"decode_tok_s_on={best['on']:.1f};ratio={ratio:.2f}")

    from benchmarks.load_harness import VirtualClock

    cfg2, eng2 = _build(quant, batch, 64, clock=VirtualClock(), trace=True)
    rng = np.random.default_rng(4)
    reqs2 = [Request(rid=i,
                     prompt=rng.integers(
                         1, cfg2.vocab_size,
                         int(rng.integers(3, 12))).tolist(),
                     max_new=4)
             for i in range(2 * batch)]
    stats = eng2.serve(reqs2)
    assert stats["done"], stats
    emitted = sum(len(r.out) for r in reqs2)
    names: dict[str, int] = {}
    for e in eng2.tracer.events():
        if e.rid is not None:
            names[e.name] = names.get(e.name, 0) + 1
    if trace_path:
        from repro.obs import dump_trace
        dump_trace(eng2.tracer, trace_path)
    if metrics_path:
        from repro.obs import dump_metrics
        dump_metrics(eng2.registry, metrics_path)
    trace_sec = {
        "requests": len(reqs2),
        "emitted_tokens": emitted,
        "submit_events": names.get("submit", 0),
        "admit_events": names.get("admit", 0),
        "first_token_events": names.get("first_token", 0),
        "token_events": names.get("token", 0),
        "finish_events": names.get("finish", 0),
        "events_total": len(eng2.tracer.events()),
        "dropped": eng2.tracer.dropped,
    }
    print(f"engine_obs_trace,0,requests={trace_sec['requests']};"
          f"emitted={emitted};"
          f"token_events={trace_sec['first_token_events'] + trace_sec['token_events']};"
          f"finish={trace_sec['finish_events']}")
    return {"decode_tok_s_off": best["off"],
            "decode_tok_s_on": best["on"],
            "overhead_ratio": ratio,
            "ticks": ticks, "repeats": repeats,
            "trace": trace_sec}


def bench_json(path: str = "BENCH_engine.json", batches=DEF_BATCHES,
               ticks: int = 6, max_seq: int = 64,
               quant: str = "bf16") -> dict:
    """Machine-readable engine numbers for the perf trajectory: decode
    tok/s, prefill tok/s and occupancy per slab width, via a short serve()
    of 2*mb mixed-length requests after a steady-state decode measurement;
    plus a ``recurrent`` section — ssm/hybrid engines serving a
    long-prompt-interleave mix under chunked prefill (the hybrid with paged
    attention pools) — a ``prefix`` section — the shared-system-prompt
    scenario, whose warm-vs-cold prefill win ``benchmarks/compare.py``
    additionally gates in CI — a ``latency`` section — per-priority
    TTFT/ITL p50/p95 from the mixed-load scenario, gated on high-priority
    p95 TTFT beating low — and a ``quant`` section — decode tok/s for
    bf16 vs the frozen-4-bit lut4/int4 decode paths on one scenario,
    whose presence (all three rows) ``compare.py`` also gates — a
    ``spec`` section — speculative decoding (baseline vs ngram vs
    self_lut on periodic prompts: acceptance rate, drafted/accepted
    counts, effective tok/s vs baseline), gated by
    ``compare.check_spec_section`` — and an ``observability`` section — tracing-on vs tracing-off decode tok/s
    (gated at ratio >= 0.97) plus trace event counts reconciled against
    token counts; its consistency run's Perfetto trace and Prometheus
    dump land in ``TRACE_engine.json`` / ``METRICS_engine.prom``.
    """
    import numpy as np

    from repro.serve.engine import Request

    out = {"model_quant": quant, "max_seq": max_seq, "ticks": ticks,
           "per_batch": {}, "recurrent": {}, "prefix": {}, "latency": {},
           "quant": {}, "spec": {}}
    for mb in batches:
        cfg, eng = _build(quant, mb, max_seq)
        decode_tok_s = _steady_decode_tok_s(eng, cfg, mb, ticks, max_seq)
        cfg, eng = _build(quant, mb, max_seq)
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i,
                        prompt=rng.integers(
                            1, cfg.vocab_size,
                            int(rng.integers(3, 12))).tolist(),
                        max_new=6)
                for i in range(2 * mb)]
        stats = eng.serve(reqs)
        out["per_batch"][str(mb)] = {
            "decode_tok_s": decode_tok_s,
            "prefill_tok_s": stats["prefill_tok_s"],
            "occupancy": stats["occupancy"],
        }
        print(f"engine_json_b{mb},0,decode_tok_s={decode_tok_s:.1f};"
              f"prefill_tok_s={stats['prefill_tok_s']:.1f};"
              f"occupancy={stats['occupancy']:.2f}")
    for arch, kw in (("mamba2-1.3b", {"prefill_chunk": 16}),
                     ("zamba2-1.2b", {"prefill_chunk": 16, "paged": True,
                                      "block_size": 16})):
        cfg, eng = _build(quant, 4, max_seq, arch=arch, **kw)
        rng = np.random.default_rng(2)
        reqs = [Request(rid=i,
                        prompt=rng.integers(
                            1, cfg.vocab_size,
                            int(rng.integers(3, max_seq - 2))).tolist(),
                        max_new=6)
                for i in range(8)]              # mixes whole + chunked
        stats = eng.serve(reqs)
        assert stats["done"] and stats["prefill_chunks"] > 0
        out["recurrent"][arch] = {
            "decode_tok_s": stats["decode_tok_s"],
            "prefill_tok_s": stats["prefill_tok_s"],
            "occupancy": stats["occupancy"],
        }
        print(f"engine_json_recurrent_{arch},0,"
              f"decode_tok_s={stats['decode_tok_s']:.1f};"
              f"prefill_tok_s={stats['prefill_tok_s']:.1f};"
              f"chunks={stats['prefill_chunks']}")
    out["prefix"] = prefix_shared_system_prompt(quant=quant)
    out["latency"] = priority_mixed_load(quant=quant)
    out["quant"] = quant_decode_modes(batch=4, ticks=ticks, max_seq=max_seq)
    out["spec"] = speculative_decode(batch=4, ticks=ticks, max_seq=max_seq)
    out["sustained"] = sustained_load()
    out["observability"] = observability_overhead(
        quant=quant, trace_path="TRACE_engine.json",
        metrics_path="METRICS_engine.prom")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"engine_json,0,wrote={path}")
    return out


def sustained_load(report_path: str = "LOAD_harness.json") -> dict:
    """Sustained-load section: deterministic virtual-time overload runs
    from the trace harness (Poisson arrivals, mixed priorities + deadline
    budgets, arrival rate far above service capacity) — goodput,
    deadline-miss rate, and per-priority TTFT/ITL percentiles are
    bit-stable, so `compare.py` gates them.  A short REAL background-loop
    run (threaded clients against `engine.start()`) rides along as the
    loop-integration smoke and lands in the detailed report written to
    ``report_path`` (the CI artifact)."""
    from benchmarks.load_harness import (build_engine, make_trace,
                                         run_threaded, sustained_report)

    out = sustained_report()
    # the same overload trace with speculative decoding on: priority
    # split and positive goodput must survive draft/verify/rollback
    out.update(sustained_report(arches=("yi-9b",), spec="ngram"))
    for arch, rep in out.items():
        print(f"engine_json_sustained_{arch},0,"
              f"goodput_tok_s={rep['goodput_tok_s']:.1f};"
              f"miss_rate={rep['deadline_miss_rate']:.2f};"
              f"ttft_p99_hi={rep['by_priority']['1']['ttft']['p99_s']:.3f};"
              f"ttft_p99_lo={rep['by_priority']['0']['ttft']['p99_s']:.3f}")
    eng, cfg = build_engine("yi-9b")
    trace = make_trace(16, 200.0, cfg.vocab_size, seed=1,
                       deadline_budgets={0: None, 1: None})
    smoke_rep = run_threaded(eng, trace, time_scale=0.01)
    assert smoke_rep["finished"] == smoke_rep["submitted"], smoke_rep
    assert smoke_rep["goodput_tok_s"] > 0, smoke_rep
    print(f"engine_json_sustained_loop_smoke,0,"
          f"finished={smoke_rep['finished']};"
          f"goodput_tok_s={smoke_rep['goodput_tok_s']:.1f}")
    with open(report_path, "w") as f:
        json.dump({"virtual": out, "threaded_smoke": smoke_rep}, f,
                  indent=2, sort_keys=True)
    # the gated section keeps only the deterministic virtual-time numbers
    # (wall-clock from the threaded smoke would flap the baseline)
    return out


def smoke() -> None:
    """Tiny CI-sized run: decode at b in (1, 4), prefill comparison, paged
    parity and the long-prompt interleaves (attention AND recurrent
    families) at reduced sizes."""
    decode_throughput(batches=(1, 4), ticks=6, max_seq=64)
    prefill_batched_vs_per_row(batch=4, prompt_len=12, max_seq=64, iters=1)
    decode_paged_vs_dense(batch=4, ticks=6, max_seq=64)
    long_prompt_interleave(max_seq=64, chunk=16)
    recurrent_long_prompt_interleave(max_seq=48, chunk=16,
                                     archs=("mamba2-1.3b",))


ALL = [decode_throughput, decode_paged_vs_dense, prefill_batched_vs_per_row,
       long_prompt_interleave, recurrent_long_prompt_interleave,
       prefix_shared_system_prompt, priority_mixed_load, quant_decode_modes,
       speculative_decode, observability_overhead]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default="bf16",
                    help="bf16, lut4/int4 (engine-level frozen decode "
                         "weights) or a model-level mode (e.g. luna_approx)")
    ap.add_argument("--batches", type=int, nargs="+",
                    default=list(DEF_BATCHES))
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--prefill-batch", type=int, default=8)
    ap.add_argument("--json", default=None,
                    help="also write BENCH_engine.json-style output here")
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.smoke:
        smoke()
        if args.json:
            bench_json(args.json)
        return
    ok = True
    decode_throughput(args.quant, tuple(args.batches), args.ticks)
    pd = decode_paged_vs_dense(args.quant, batch=8, ticks=args.ticks)
    if pd["ratio"] < 0.6:        # CPU timing is noisy; gate gross regressions
        print(f"engine_paged_regression,FAIL,"
              f"paged_much_slower_than_dense={pd['ratio']:.2f}")
        ok = False
    res = prefill_batched_vs_per_row(args.quant, args.prefill_batch)
    long_prompt_interleave(quant=args.quant)
    recurrent_long_prompt_interleave(quant=args.quant)
    if args.json:
        bench_json(args.json, quant=args.quant)
    if res["speedup"] <= 1.0:
        print(f"engine_prefill_regression,FAIL,"
              f"batched_slower_than_per_row={res['speedup']:.2f}")
        ok = False
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
