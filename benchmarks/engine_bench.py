"""Serving-engine benchmarks: decode throughput vs slab width, and batched
(bucketed) prefill vs per-row prefill.

Prints the orchestrator's ``name,us_per_call,derived`` CSV rows.  Timings on
CPU are correctness-level; the derived column carries the quantities that
transfer (tokens/s, per-token cost, speedup ratios).

  PYTHONPATH=src python benchmarks/engine_bench.py --quant luna_approx
"""
from __future__ import annotations

import argparse
import os
import sys
import time

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

DEF_BATCHES = (1, 8, 32)


def _build(quant: str, max_batch: int, max_seq: int):
    import jax

    from repro.core.layers import QuantConfig
    from repro.models.registry import get_config, get_model
    from repro.serve.engine import Engine

    cfg = get_config("yi-9b").reduced()
    if quant != "bf16":
        from dataclasses import replace
        cfg = replace(cfg, quant=QuantConfig(mode=quant))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, Engine(cfg, params, max_batch=max_batch, max_seq=max_seq)


def decode_throughput(quant: str = "bf16", batches=DEF_BATCHES,
                      ticks: int = 24, max_seq: int = 128) -> dict:
    """Steady-state decode tokens/s with every slot occupied, per slab width.

    Fills the slab, burns warm-up ticks (jit compile + cache), then times
    ``ticks`` decode steps.
    """
    import numpy as np

    from repro.serve.engine import Request

    rows = {}
    for mb in batches:
        cfg, eng = _build(quant, mb, max_seq)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                        max_new=max_seq)       # never finishes mid-bench
                for i in range(mb)]
        for i, r in enumerate(reqs):
            assert eng.submit(r), i
        for _ in range(3):                      # warm-up (compile) ticks
            eng.step()
        eng.metrics.decode_s = 0.0
        eng.metrics.decode_tokens = 0
        t0 = time.perf_counter()
        for _ in range(ticks):
            eng.step()
        wall = time.perf_counter() - t0
        toks = eng.metrics.decode_tokens
        tok_s = toks / max(wall, 1e-9)
        us = wall / ticks * 1e6
        rows[mb] = tok_s
        print(f"engine_decode_b{mb},{us:.0f},"
              f"tok_s={tok_s:.1f};quant={quant};ticks={ticks}")
    if 1 in rows:
        for mb in batches:
            if mb != 1:
                print(f"engine_decode_scaling_b{mb},0,"
                      f"tok_s_ratio_vs_b1={rows[mb] / rows[1]:.2f}")
    return rows


def prefill_batched_vs_per_row(quant: str = "bf16", batch: int = 8,
                               prompt_len: int = 24, max_seq: int = 128,
                               iters: int = 3) -> dict:
    """One bucketed prefill call + slab scatter vs per-row prefill calls.

    Same prompts, same slab; per-row mode submits each request alone (the
    seed engine's strategy), batched mode admits them as one bucket.
    """
    import numpy as np

    from repro.serve.engine import Request

    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, 500, prompt_len).tolist()
               for _ in range(batch)]

    def _run(batched: bool) -> float:
        cfg, eng = _build(quant, batch, max_seq)
        vocab = cfg.vocab_size
        ps = [[t % vocab for t in p] for p in prompts]
        best = float("inf")
        for it in range(iters + 1):             # iter 0 = compile warm-up
            eng.slots = [None] * batch
            eng.active.clear()
            t0 = time.perf_counter()
            if batched:
                reqs = [Request(rid=it * batch + i, prompt=p, max_new=4)
                        for i, p in enumerate(ps)]
                eng._admit(reqs, list(range(batch)))
            else:
                for i, p in enumerate(ps):
                    assert eng.submit(
                        Request(rid=it * batch + i, prompt=p, max_new=4))
            wall = time.perf_counter() - t0
            if it > 0:
                best = min(best, wall)
        return best

    per_row = _run(batched=False)
    batched = _run(batched=True)
    speedup = per_row / max(batched, 1e-9)
    print(f"engine_prefill_per_row_b{batch},{per_row * 1e6:.0f},"
          f"len={prompt_len};quant={quant}")
    print(f"engine_prefill_batched_b{batch},{batched * 1e6:.0f},"
          f"speedup_vs_per_row={speedup:.2f}")
    return {"per_row_s": per_row, "batched_s": batched, "speedup": speedup}


def smoke() -> None:
    """Tiny CI-sized run: decode at b in (1, 4) + prefill comparison at 4."""
    decode_throughput(batches=(1, 4), ticks=6, max_seq=64)
    prefill_batched_vs_per_row(batch=4, prompt_len=12, max_seq=64, iters=1)


ALL = [decode_throughput, prefill_batched_vs_per_row]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quant", default="bf16",
                    help="bf16 or a luna_* mode (e.g. luna_approx)")
    ap.add_argument("--batches", type=int, nargs="+",
                    default=list(DEF_BATCHES))
    ap.add_argument("--ticks", type=int, default=24)
    ap.add_argument("--prefill-batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.smoke:
        smoke()
        return
    ok = True
    decode_throughput(args.quant, tuple(args.batches), args.ticks)
    res = prefill_batched_vs_per_row(args.quant, args.prefill_batch)
    if res["speedup"] <= 1.0:
        print(f"engine_prefill_regression,FAIL,"
              f"batched_slower_than_per_row={res['speedup']:.2f}")
        ok = False
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
