"""Benchmark orchestrator: one function per paper table/figure + kernel and
roofline benches.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0

    from benchmarks import paper_tables
    for fn in paper_tables.ALL:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},FAIL,{traceback.format_exc(limit=1)!r}")

    from benchmarks import kernel_bench
    for fn in kernel_bench.ALL:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},FAIL,{traceback.format_exc(limit=1)!r}")

    # roofline summary from the dry-run artifacts (if the sweep has run)
    try:
        from benchmarks import roofline_report
        roofline_report.summary_csv()
    except Exception:  # noqa: BLE001
        print("roofline_report,SKIP,run `python -m repro.launch.dryrun --all`"
              " first")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
