"""Benchmark orchestrator: one function per paper table/figure + kernel,
engine and roofline benches.  Prints ``name,us_per_call,derived`` CSV.

``--smoke`` runs a minutes-not-hours subset (CI uploads its CSV as an
artifact): one kernel bench + the serving-engine smoke, and writes
``BENCH_engine.json`` (decode/prefill tok/s + occupancy per slab width,
recurrent chunked-prefill scenarios, and the prefix-cache
shared-system-prompt warm-vs-cold section) so the perf trajectory
accumulates across commits.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for _p in (_ROOT, os.path.join(_ROOT, "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


def _run(fns, failures: int) -> int:
    for fn in fns:
        try:
            fn()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{fn.__name__},FAIL,{traceback.format_exc(limit=1)!r}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small CI subset: kernel modes + engine smoke")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failures = 0

    from benchmarks import engine_bench, kernel_bench

    if args.smoke:
        failures = _run([kernel_bench.luna_mm_modes, engine_bench.smoke,
                         engine_bench.bench_json],
                        failures)
        if failures:
            sys.exit(1)
        return

    from benchmarks import paper_tables
    failures = _run(paper_tables.ALL, failures)
    failures = _run(kernel_bench.ALL, failures)
    failures = _run(engine_bench.ALL, failures)

    # roofline summary from the dry-run artifacts (if the sweep has run)
    try:
        from benchmarks import roofline_report
        roofline_report.summary_csv()
    except Exception:  # noqa: BLE001
        print("roofline_report,SKIP,run `python -m repro.launch.dryrun --all`"
              " first")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
