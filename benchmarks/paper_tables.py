"""Benchmarks reproducing every LUNA-CIM table/figure (one function each).

Each function prints ``name,us_per_call,derived`` CSV rows (derived = the
paper-comparable quantity) and returns a dict for programmatic use.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model as cm
from repro.core import luna
from repro.core.luna import LunaMode


def _time(fn, *args, iters=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / iters * 1e6


def table1() -> dict:
    """Paper Table I: conventional-LUT storage/mux growth 3b..8b."""
    rows = {}
    for bits in range(3, 9):
        c = cm.conventional_cost(bits)
        rows[bits] = (c.srams, c.muxes)
        print(f"table1_{bits}b,0,srams={c.srams};muxes={c.muxes}")
    expected = {3: (48, 42), 4: (128, 120), 5: (320, 310), 6: (768, 756),
                7: (1792, 1778), 8: (4096, 4080)}
    assert rows == expected, rows
    return rows


def table2() -> dict:
    """Paper Table II: traditional vs optimized D&C for 4/8/16 b."""
    rows = {}
    for bits in (4, 8, 16):
        t = cm.conventional_cost(bits)
        o = cm.opt_dc_cost(bits)
        rows[bits] = {"trad": (t.srams, t.muxes),
                      "opt": (o.srams, o.muxes, o.has, o.fas)}
        print(f"table2_{bits}b,0,trad_srams={t.srams};opt_srams={o.srams};"
              f"opt_muxes={o.muxes};opt_has={o.has};opt_fas={o.fas}")
    assert rows[16]["opt"] == (136, 432, 31, 105)
    return rows


def fig5() -> dict:
    """LSB-side product distribution; P(0) = 0.296."""
    vals, probs, _ = luna.lsb_product_distribution()
    us = _time(lambda: luna.lsb_product_distribution.__wrapped__())
    print(f"fig5,{us:.1f},p_zero={probs[0]:.4f}")
    return {"p_zero": float(probs[0]),
            "impossible": luna.impossible_lsb_products()}


def fig6() -> dict:
    """Hamming-distance-optimal Z_LSB approx: argmin 0, HD 0.275."""
    cands, hd = luna.hamming_distance_profile()
    us = _time(luna.hamming_distance_profile)
    print(f"fig6,{us:.1f},argmin={int(np.argmin(hd))};min_hd={hd.min():.4f}")
    return {"argmin": int(np.argmin(hd)), "min_hd": float(hd.min())}


def fig8() -> dict:
    """ApproxD&C error histogram: range [0, 45]."""
    err = luna.error_table(LunaMode.APPROX_DC)
    hist = np.bincount(err.ravel(), minlength=46)
    print(f"fig8,0,err_min={err.min()};err_max={err.max()};"
          f"mae={np.abs(err).mean():.3f}")
    return {"min": int(err.min()), "max": int(err.max()), "hist": hist}


def fig12() -> dict:
    """ApproxD&C2 error histogram: range [-15, 30], balanced."""
    err = luna.error_table(LunaMode.APPROX_DC2)
    print(f"fig12,0,err_min={err.min()};err_max={err.max()};"
          f"mean={err.mean():.3f};mae={np.abs(err).mean():.3f}")
    return {"min": int(err.min()), "max": int(err.max()),
            "mean": float(err.mean())}


def fig13() -> dict:
    """NN-level MAE per multiplier mode (paper's MATLAB experiment).

    Trains one small MLP regressor, then evaluates its forward pass with
    each multiplier mode; MAE is vs the IDEAL (f32) forward, averaged over
    100 random input batches — matching the paper's protocol.
    """
    from repro.core.quant import luna_matmul_f32
    rng = np.random.default_rng(0)
    d_in, d_h, d_out = 16, 32, 4
    w1 = jnp.asarray(rng.normal(size=(d_in, d_h)) * 0.5, jnp.float32)
    w2 = jnp.asarray(rng.normal(size=(d_h, d_out)) * 0.5, jnp.float32)

    def fwd(x, mode):
        if mode == "ideal":
            h = jax.nn.relu(x @ w1)
            return h @ w2
        h = jax.nn.relu(luna_matmul_f32(x, w1, mode, bits=4))
        return luna_matmul_f32(h, w2, mode, bits=4)

    maes = {}
    for mode in ("ideal", LunaMode.OPT_DC, LunaMode.APPROX_DC2,
                 LunaMode.APPROX_DC):
        tot = 0.0
        for it in range(100):          # paper: 100 iterations
            x = jnp.asarray(rng.normal(size=(8, d_in)), jnp.float32)
            ref = fwd(x, "ideal")
            out = fwd(x, mode)
            tot += float(jnp.abs(out - ref).mean())
        maes[str(mode)] = tot / 100
        print(f"fig13_{mode},0,mae={maes[str(mode)]:.4f}")
    assert maes["ideal"] == 0.0
    # paper ordering: exact D&C < ApproxD&C2 < ApproxD&C (balanced error wins)
    assert maes[str(LunaMode.OPT_DC)] <= maes[str(LunaMode.APPROX_DC)]
    return maes


def fig14() -> dict:
    """Transient-sim re-enactment: W=0110 fixed, Y in {1010,1011,0011,1100}."""
    w = 0b0110
    outs = {}
    for y in (0b1010, 0b1011, 0b0011, 0b1100):
        z = int(luna.luna_product(jnp.int32(w), jnp.int32(y), 4,
                                  LunaMode.OPT_DC))
        outs[f"{y:04b}"] = f"{z:08b}"
        assert z == w * y
    print(f"fig14,0,{';'.join(f'Y={k}->OUT={v}' for k, v in outs.items())}")
    return outs


def fig15() -> dict:
    """Energy: multiplier = 47.96 fJ = 0.0276 % of SRAM write energy."""
    rep = cm.energy_report()
    print(f"fig15,0,mult_share={rep['multiplier_share']*100:.4f}%")
    return rep


def fig16() -> dict:
    """Area comparison across variants (transistor model); opt D&C ~3.7x."""
    rep = cm.area_report(4)
    ratio = rep["opt_dc"]["area_vs_conventional"]
    print(f"fig16,0,opt_dc_vs_conventional={ratio:.2f}x;"
          f"approx_dc={rep['approx_dc']['area_vs_conventional']:.2f}x")
    return rep


def fig18() -> dict:
    """Array overhead: 4 LUNA units on 8x8 SRAM = 32 %."""
    rep = cm.array_overhead(4)
    print(f"fig18,0,overhead={rep['overhead_fraction']*100:.1f}%")
    return rep


ALL = [table1, table2, fig5, fig6, fig8, fig12, fig13, fig14, fig15, fig16,
       fig18]
