"""Kernel microbenchmarks (CPU interpret timings are correctness-level only;
the derived column reports the structural quantities that transfer to TPU:
MXU-matmul counts per output tile and VMEM working-set bytes)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _bench(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def luna_mm_modes(m=256, k=512, n=256) -> dict:
    """Digit-plane LUNA GEMM: approx modes halve the MXU matmul count."""
    from repro.kernels.luna_mm.ops import luna_mm_codes
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int8)
    rows = {}
    mxu_matmuls = {"conventional": 1, "opt_dc": 2, "approx_dc": 1,
                   "approx_dc2": 1}
    for mode, nmm in mxu_matmuls.items():
        us = _bench(lambda mo=mode: luna_mm_codes(y, w, mode=mo,
                                                  interpret=True))
        # int8 MXU work per output tile, relative to exact D&C
        rel = nmm / mxu_matmuls["opt_dc"]
        rows[mode] = us
        print(f"luna_mm_{mode},{us:.0f},mxu_matmuls={nmm};rel_mxu={rel:.2f}")
    return rows


def lut_gemm_bench(m=128, k=256, n=128) -> dict:
    """Codebook LUT GEMM: 15 selects/tile (the paper's mux count) + 1 matmul."""
    from repro.kernels.lut_gemm.ops import nf4_matmul_kernel
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    us = _bench(lambda: nf4_matmul_kernel(x, w, interpret=True))
    vmem_tile = 128 * 256 * 1 + 256 * 128 * 4 + 128 * 128 * 4  # codes+deq+acc
    print(f"lut_gemm_nf4,{us:.0f},selects_per_tile=15;"
          f"vmem_tile_bytes={vmem_tile}")
    return {"us": us}


def lut_gemm_vs_dense_sweep(shapes=((8, 256, 512), (8, 512, 512),
                                    (128, 256, 512))) -> dict:
    """Decode-shape sweep: dense jnp.dot vs the D&C sub-table LUT gemm vs
    the full-codebook kernel (6 vs 15 selects per tile — the paper's ~3.7x
    LUT-area split at the GEMM level), plus the residual-corrected
    non-affine path (nf4 D&C = 6 selects + one per-code residual gather)
    against the affine 6-select baseline, so the residual epilogue's
    overhead is visible per shape.

    The jnp D&C path is what the serving engine runs on the decode hot
    path (``EngineConfig(quant="lut4"|"nf4")``); the Pallas kernels are
    timed in interpret mode, so their numbers track structure (weight
    bytes moved: 4-bit codes vs 16-bit floats), not real TPU wall-clock.
    """
    from repro.core.quant import quantize_weight
    from repro.kernels.lut_gemm.ops import (lut4_matmul_kernel,
                                            nf4_matmul_kernel,
                                            nf4dc_matmul_kernel,
                                            quantized_matmul)
    rng = np.random.default_rng(1)
    out = {}
    for m, k, n in shapes:
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        qw = quantize_weight(w, "lut_dc")
        qw_nf4 = quantize_weight(w, "nf4_dc")
        us_dense = _bench(lambda: x @ w)
        us_jnp = _bench(lambda: quantized_matmul(x, qw))
        us_dc = _bench(lambda: lut4_matmul_kernel(x, w, interpret=True))
        us_full = _bench(lambda: nf4_matmul_kernel(x, w, interpret=True))
        us_nf4_jnp = _bench(lambda: quantized_matmul(x, qw_nf4))
        us_nf4_dc = _bench(lambda: nf4dc_matmul_kernel(x, w, interpret=True))
        wbytes_dense = k * n * 2                       # bf16 weights
        wbytes_lut = k * n // 2 + n * 8                # 4-bit codes + scales
        tag = f"m{m}_k{k}_n{n}"
        out[tag] = {"dense_us": us_dense, "lut_dc_jnp_us": us_jnp,
                    "lut_dc_pallas_us": us_dc, "lut_full_pallas_us": us_full,
                    "nf4_dc_jnp_us": us_nf4_jnp,
                    "nf4_dc_pallas_us": us_nf4_dc,
                    "residual_overhead": us_nf4_dc / max(us_dc, 1e-9)}
        print(f"lut_gemm_sweep_{tag},{us_jnp:.0f},dense_us={us_dense:.0f};"
              f"dc_pallas_us={us_dc:.0f};full_pallas_us={us_full:.0f};"
              f"weight_bytes={wbytes_lut}_vs_{wbytes_dense};"
              f"selects=6_vs_15")
        print(f"lut_gemm_sweep_nf4_{tag},{us_nf4_jnp:.0f},"
              f"nf4_dc_pallas_us={us_nf4_dc:.0f};"
              f"residual_vs_affine={us_nf4_dc / max(us_dc, 1e-9):.2f}x;"
              f"selects=6+res_vs_6")
    return out


def flash_bench(s=1024, h=4, d=64) -> dict:
    from repro.kernels.flash_attention.ops import mha
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, s, h, d)), jnp.float32)
    us_flash = _bench(lambda: mha(q, k, v, sm_scale=0.125, use_flash=True,
                                  interpret=True))
    us_ref = _bench(lambda: mha(q, k, v, sm_scale=0.125, use_flash=False))
    # structural: flash never materializes the (S,S) score matrix
    print(f"flash_attention,{us_flash:.0f},score_bytes_saved="
          f"{s*s*h*4};ref_us={us_ref:.0f}")
    return {"flash_us": us_flash, "ref_us": us_ref}


def quant_model_bench() -> dict:
    """End-to-end: reduced yi-9b forward under each quant mode."""
    from repro.models.registry import get_config, get_model
    from repro.core.layers import QuantConfig
    rows = {}
    rng = np.random.default_rng(3)
    for mode in ("bf16", "int8", "luna_dc", "luna_approx", "luna_approx2"):
        cfg = get_config("yi-9b").reduced(quant=QuantConfig(mode=mode))
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64))),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)))}
        fn = jax.jit(lambda p: model.loss(p, batch)[0])
        us = _bench(fn, params)
        rows[mode] = us
        print(f"e2e_quant_{mode},{us:.0f},loss={float(fn(params)):.3f}")
    return rows


ALL = [luna_mm_modes, lut_gemm_bench, lut_gemm_vs_dense_sweep, flash_bench,
       quant_model_bench]
