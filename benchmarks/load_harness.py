"""Trace-based load harness: open-loop traffic against the serving engine.

Two drive modes over the same trace format:

* **virtual** (`run_virtual`) — the engine is built with a
  :class:`VirtualClock`; the harness delivers each arrival the moment
  virtual time reaches it (`engine.serve([req], max_ticks=0)` enqueues
  without ticking), hand-ticks the engine, and advances the clock by a
  fixed per-tick cost.  Every timestamp the engine stamps (submit, token,
  deadline comparisons) lands on the virtual clock, so goodput,
  deadline-miss rate, and TTFT/ITL percentiles are **bit-deterministic**
  across runs and machines — this mode produces the gated "sustained"
  section of `BENCH_engine.json`.
* **threaded** (`run_threaded`) — the real thing: `engine.start()` runs
  the background serve loop, a `ThreadPoolExecutor` of client threads
  (the SNIPPETS Snippet-2 harness idiom) sleeps each request until its
  arrival time, `submit()`s against the running loop, and consumes
  `handle.tokens()` concurrently.  Wall-clock numbers; used as the
  loop-integration smoke (goodput > 0), not for gating.

Traces are open-loop (arrival times fixed up front, independent of
service — the honest way to measure overload): Poisson arrivals with
mixed priorities, prompt lengths, and per-priority deadline budgets.

Run standalone:

  PYTHONPATH=src python benchmarks/load_harness.py --arch yi-9b \
      --requests 64 --rate 200 --out LOAD_harness.json
"""
from __future__ import annotations

import argparse
import json
import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field

import numpy as np


class VirtualClock:
    """Deterministic engine clock: a monotonic counter advanced by hand.
    Inject via ``Engine(cfg, params, config, clock=VirtualClock())`` —
    every ``submit_ts``/``token_ts``/deadline comparison then lives in
    virtual seconds."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        assert dt >= 0, dt
        self.now += dt
        return self.now


@dataclass
class TraceItem:
    """One scheduled arrival: request shape + when it hits the engine.
    ``deadline_budget`` is seconds from arrival to the first-token
    deadline (None = no deadline)."""
    at: float
    prompt: list[int]
    max_new: int
    priority: int = 0
    deadline_budget: float | None = None


@dataclass
class TraceStats:
    """Per-run accounting produced by :func:`summarize`."""
    report: dict = field(default_factory=dict)


def make_trace(n: int, rate: float, vocab: int, seed: int = 0,
               prompt_lens=(4, 8, 12, 24), max_new: int = 8,
               priorities=((0, 0.7), (1, 0.3)),
               deadline_budgets={0: None, 1: 0.5}) -> list[TraceItem]:
    """Open-loop Poisson trace: exponential inter-arrival gaps at ``rate``
    req/s, prompt lengths and priority classes drawn from the given
    mixes.  Fully determined by ``seed``."""
    rng = np.random.default_rng(seed)
    classes = [p for p, _ in priorities]
    weights = np.asarray([w for _, w in priorities], float)
    weights = weights / weights.sum()
    t = 0.0
    trace = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate))
        prio = int(rng.choice(classes, p=weights))
        plen = int(rng.choice(prompt_lens))
        prompt = rng.integers(1, vocab, plen).tolist()
        trace.append(TraceItem(at=t, prompt=prompt, max_new=max_new,
                               priority=prio,
                               deadline_budget=deadline_budgets.get(prio)))
    return trace


def _percentiles(xs: list[float]) -> dict:
    if not xs:
        return {"p50_s": None, "p99_s": None}
    a = np.asarray(xs, float)
    return {"p50_s": float(np.percentile(a, 50)),
            "p99_s": float(np.percentile(a, 99))}


def summarize(reqs: list, duration_s: float) -> dict:
    """Goodput / deadline / latency report over served requests.  Goodput
    counts only tokens of requests that FINISHED and (when they carried a
    deadline) got their first token in time — late work is throughput,
    not goodput."""
    def ttft(r):
        return r.token_ts[0] - r.submit_ts if r.token_ts else None

    def itls(r):
        return [b - a for a, b in zip(r.token_ts, r.token_ts[1:])]

    finished = [r for r in reqs if r.done and not r.cancelled]
    with_dl = [r for r in finished if r.deadline is not None and r.token_ts]
    missed = [r for r in with_dl if r.token_ts[0] > r.deadline]
    good = [r for r in finished
            if r.deadline is None or (r.token_ts
                                      and r.token_ts[0] <= r.deadline)]
    by_priority = {}
    for prio in sorted({r.priority for r in reqs}):
        rs = [r for r in finished if r.priority == prio]
        tt = [ttft(r) for r in rs if r.token_ts]
        by_priority[str(prio)] = {
            "finished": len(rs),
            "ttft": _percentiles(tt),
            "itl": _percentiles([g for r in rs for g in itls(r)]),
        }
    return {
        "submitted": len(reqs),
        "finished": len(finished),
        "duration_s": duration_s,
        "goodput_tok_s": (sum(len(r.out) for r in good)
                          / max(duration_s, 1e-9)),
        "throughput_tok_s": (sum(len(r.out) for r in finished)
                             / max(duration_s, 1e-9)),
        "deadline_requests": len(with_dl),
        "deadline_misses": len(missed),
        "deadline_miss_rate": (len(missed) / len(with_dl)
                               if with_dl else 0.0),
        "ttft": _percentiles([ttft(r) for r in finished if r.token_ts]),
        "itl": _percentiles([g for r in finished for g in itls(r)]),
        "by_priority": by_priority,
    }


def run_virtual(engine, trace: list[TraceItem], tick_cost_s: float = 0.01,
                max_ticks: int = 100_000) -> dict:
    """Deterministic drive: the engine's clock MUST be a
    :class:`VirtualClock`.  Arrivals are enqueued exactly at their trace
    time (``submit_ts`` pinned to the intended arrival, so queueing delay
    under overload is charged to TTFT), each tick costs ``tick_cost_s``
    virtual seconds, and the run ends when the trace is drained and the
    engine idles."""
    from repro.serve.engine import Request

    vc = engine.clock
    assert isinstance(vc, VirtualClock), \
        "run_virtual needs Engine(..., clock=VirtualClock())"
    t_start = vc.now
    reqs = []
    i, ticks = 0, 0
    while (i < len(trace) or not engine.idle) and ticks < max_ticks:
        while i < len(trace) and trace[i].at <= vc.now:
            it = trace[i]
            req = Request(rid=i, prompt=list(it.prompt), max_new=it.max_new,
                          priority=it.priority,
                          deadline=(it.at + it.deadline_budget
                                    if it.deadline_budget is not None
                                    else None))
            req.submit_ts = it.at
            engine.serve([req], max_ticks=0)       # enqueue, no ticking
            reqs.append(req)
            i += 1
        if engine.idle:
            vc.advance(trace[i].at - vc.now)       # jump to next arrival
            continue
        vc.advance(tick_cost_s)
        engine.step()
        ticks += 1
    rep = summarize(reqs, vc.now - t_start)
    rep.update({"mode": "virtual", "ticks": ticks,
                "tick_cost_s": tick_cost_s,
                "drained": engine.idle and i == len(trace)})
    return rep


def run_threaded(engine, trace: list[TraceItem], time_scale: float = 1.0,
                 max_workers: int = 8) -> dict:
    """Real-time drive against the background serve loop: one client task
    per trace item sleeps until its (scaled) arrival, submits, and
    consumes the handle's token stream.  Wall-clock, so numbers are
    machine-dependent — smoke only."""
    from repro.serve.engine import Request

    started_here = not engine.running
    engine.start()
    base = engine.clock()
    reqs = [None] * len(trace)

    def client(i: int):
        it = trace[i]
        delay = base + it.at * time_scale - engine.clock()
        if delay > 0:
            time.sleep(delay)
        req = Request(rid=i, prompt=list(it.prompt), max_new=it.max_new,
                      priority=it.priority,
                      deadline=(engine.clock() + it.deadline_budget
                                if it.deadline_budget is not None
                                else None))
        reqs[i] = req
        handle = engine.submit(req)
        stream = list(handle.tokens())
        assert stream == req.out, f"rid {i}: stream diverged from req.out"
        return len(stream)

    with ThreadPoolExecutor(max_workers=max_workers) as pool:
        list(pool.map(client, range(len(trace))))
    if started_here:
        engine.stop()
    rep = summarize([r for r in reqs if r is not None],
                    engine.clock() - base)
    rep.update({"mode": "threaded", "time_scale": time_scale})
    return rep


def build_engine(arch: str = "yi-9b", *, clock=None, max_batch: int = 2,
                 max_seq: int = 64, **knobs):
    """Tiny reduced-config engine for harness runs (mirrors the bench
    builder; float32 so every platform agrees)."""
    import jax

    from repro.models.registry import get_config, get_model
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Engine

    cfg = get_config(arch).reduced(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    config = EngineConfig(max_batch=max_batch, max_seq=max_seq, **knobs)
    return Engine(cfg, params, config, clock=clock), cfg


def sustained_report(arches=("yi-9b", "mamba2-1.3b"), n: int = 48,
                     rate: float = 100.0, tick_cost_s: float = 0.01,
                     seed: int = 0, spec: str | None = None) -> dict:
    """The gated sustained-load numbers: per arch, one deterministic
    virtual-time overload run (arrival rate far above service capacity so
    the scheduler's priority/deadline machinery is actually exercised).
    Deadline budgets are sized so the low-priority class misses under
    overload while high-priority work mostly holds.  ``spec`` runs the
    engines with that speculative draft proposer (report keys become
    ``<arch>+spec_<mode>``) — the scheduler properties must hold under
    draft/verify/rollback too."""
    out = {}
    knobs = {"spec": spec} if spec else {}
    for arch in arches:
        eng, cfg = build_engine(arch, clock=VirtualClock(), **knobs)
        trace = make_trace(n, rate, cfg.vocab_size, seed=seed,
                           deadline_budgets={0: 0.8, 1: 0.5})
        key = f"{arch}+spec_{spec}" if spec else arch
        out[key] = run_virtual(eng, trace, tick_cost_s=tick_cost_s)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--rate", type=float, default=100.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tick-cost-s", type=float, default=0.01)
    ap.add_argument("--spec", default=None,
                    help="speculative draft proposer for the run "
                         "('ngram' or 'self_lut'; default off)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="max drafts per request per tick under --spec")
    ap.add_argument("--threaded", action="store_true",
                    help="also run the real background-loop drive")
    ap.add_argument("--time-scale", type=float, default=0.02,
                    help="threaded mode: wall seconds per trace second")
    ap.add_argument("--out", default="LOAD_harness.json")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record the virtual run's request-lifecycle trace "
                         "and write Perfetto JSON (byte-deterministic: the "
                         "tracer stamps from the virtual clock)")
    ap.add_argument("--metrics-dump", default=None, metavar="PATH",
                    help="write the virtual engine's Prometheus text "
                         "exposition after the run")
    args = ap.parse_args()

    report = {"arch": args.arch, "requests": args.requests,
              "rate_rps": args.rate, "seed": args.seed}
    knobs = {"trace": True} if args.trace_out else {}
    if args.spec:
        knobs["spec"] = args.spec
        report["spec"] = args.spec
        if args.spec_k is not None:
            knobs["spec_k"] = args.spec_k
    eng, cfg = build_engine(args.arch, clock=VirtualClock(), **knobs)
    trace = make_trace(args.requests, args.rate, cfg.vocab_size,
                       seed=args.seed, deadline_budgets={0: 0.8, 1: 0.5})
    report["virtual"] = run_virtual(eng, trace,
                                    tick_cost_s=args.tick_cost_s)
    if args.trace_out:
        from repro.obs import dump_trace
        dump_trace(eng.tracer, args.trace_out)
        report["trace_events"] = len(eng.tracer.events())
    if args.metrics_dump:
        from repro.obs import dump_metrics
        dump_metrics(eng.registry, args.metrics_dump)
    if args.threaded:
        eng2, _ = build_engine(args.arch)
        report["threaded"] = run_threaded(eng2, trace,
                                          time_scale=args.time_scale)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
    v = report["virtual"]
    print(f"[load_harness] {args.arch}: goodput "
          f"{v['goodput_tok_s']:.1f} tok/s (virtual), deadline miss "
          f"{v['deadline_miss_rate']:.0%} "
          f"({v['deadline_misses']}/{v['deadline_requests']}), "
          f"ttft p99 {v['ttft']['p99_s']:.3f}s -> {args.out}")


if __name__ == "__main__":
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    main()
