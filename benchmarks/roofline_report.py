"""Roofline report: reads results/dryrun/*.json into the EXPERIMENTS.md
tables (and a CSV summary for benchmarks/run.py)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(mesh: str | None = None, quant: str = "bf16") -> list[dict]:
    cells = []
    for p in sorted(RESULTS.glob("*.json")):
        rec = json.loads(p.read_text())
        rec["_file"] = p.name
        if rec.get("quant", "bf16") != quant and rec.get("status") == "ok":
            continue
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def summary_csv():
    for rec in load_cells(mesh="16x16"):
        if rec.get("status") != "ok":
            continue
        name = f"roofline_{rec['arch']}_{rec['shape']}"
        print(f"{name},0,dominant={rec['dominant']};"
              f"fraction={rec['roofline_fraction']:.4f};"
              f"compute_s={rec['compute_s']:.3e};"
              f"memory_s={rec['memory_s']:.3e};"
              f"collective_s={rec['collective_s']:.3e}")


def markdown_table(mesh: str = "16x16") -> str:
    """Full roofline table for EXPERIMENTS.md."""
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful ratio | roofline frac | "
            "peak GiB/dev |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    skips = []
    for rec in load_cells(mesh=None):
        if rec.get("status") == "skip":
            continue
        if rec.get("mesh") != mesh:
            continue
        if rec.get("status") != "ok":
            rows.append(f"| {rec.get('arch','?')} | {rec.get('shape','?')} |"
                        f" FAIL | | | | | | | |")
            continue
        m = rec["memory_analysis"]["bytes_per_device_peak_estimate"] / 2**30
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['compute_s']:.2e} "
            f"| {rec['memory_s']:.2e} | {rec['collective_s']:.2e} "
            f"| {rec['dominant'].replace('_s','')} | {rec['model_flops']:.2e} "
            f"| {rec['useful_flops_ratio']:.2f} "
            f"| {rec['roofline_fraction']:.3f} | {m:.1f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    print(markdown_table())
