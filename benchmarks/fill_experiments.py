"""Regenerate the generated sections of EXPERIMENTS.md from results/dryrun."""
from __future__ import annotations

import json
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"
EXP = ROOT / "EXPERIMENTS.md"


def load(mesh=None):
    out = []
    for p in sorted(RESULTS.glob("*.json")):
        r = json.loads(p.read_text())
        r["_file"] = p.name
        if mesh and r.get("mesh") != mesh and r.get("status") == "ok":
            continue
        out.append(r)
    return out


def dryrun_summary() -> str:
    cells = load()
    ok = [r for r in cells if r["status"] == "ok"]
    skip = [r for r in cells if r["status"] == "skip"]
    fail = [r for r in cells if r["status"] == "fail"]
    sp = [r for r in ok if r["mesh"] == "16x16"]
    mp = [r for r in ok if r["mesh"] == "2x16x16"]
    lines = [
        f"**Status**: {len(ok)} cell-lowerings compiled OK "
        f"({len(sp)} on 16x16, {len(mp)} on 2x16x16 multi-pod), "
        f"{len(skip)} skipped per assignment rules (long_500k on "
        f"full-attention archs), {len(fail)} failed.",
        "",
        "Largest per-device footprints (peak = arguments + temporaries):",
        "",
        "| cell | mesh | peak GiB/dev | compile s |",
        "|---|---|---|---|",
    ]
    for r in sorted(ok, key=lambda r: -r["memory_analysis"]
                    ["bytes_per_device_peak_estimate"])[:8]:
        m = r["memory_analysis"]["bytes_per_device_peak_estimate"] / 2**30
        lines.append(f"| {r['arch']} x {r['shape']} | {r['mesh']} "
                     f"| {m:.1f} | {r['compile_s']} |")
    lines.append("")
    lines.append("Collective mix across all OK cells (payload bytes): ")
    agg = {}
    for r in ok:
        for k, v in r["collective_breakdown"].items():
            agg[k] = agg.get(k, 0) + v
    tot = sum(agg.values()) or 1
    lines.append(", ".join(f"{k} {100*v/tot:.0f}%" for k, v in
                           sorted(agg.items(), key=lambda kv: -kv[1])))
    return "\n".join(lines)


def roofline_table(mesh="16x16") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful | frac | MFU | peak GiB |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in load():
        if r.get("mesh") != mesh:
            if r.get("status") == "skip" and r["_file"].endswith("sp.json"):
                arch, shape, _ = r["_file"].split("__")
                rows.append(f"| {arch} | {shape} | SKIP (sub-quadratic-"
                            f"attention rule) | | | | | | | | |")
            continue
        if r["status"] != "ok":
            continue
        m = r["memory_analysis"]["bytes_per_device_peak_estimate"] / 2**30
        chips = r["chips"]
        mfu = (r["model_flops"] / (chips * 197e12)) / r["step_time_lb_s"]
        note = "*" if r.get("accounting") else ""
        rows.append(
            f"| {r['arch']}{note} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| {r['dominant'].replace('_s', '')} | {r['model_flops']:.2e} "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} "
            f"| {mfu:.4f} | {m:.1f} |")
    rows.append("")
    rows.append("`*` = analytic-FLOPs accounting (SSD probe fallback, "
                "DESIGN.md §10); all other cells use probe extrapolation "
                "(residual < 1e-12).")
    return "\n".join(rows)


def analysis() -> str:
    cells = [r for r in load(mesh="16x16") if r["status"] == "ok"]
    by_dom = {}
    for r in cells:
        by_dom.setdefault(r["dominant"], []).append(r)
    lines = []
    for dom, rs in sorted(by_dom.items(), key=lambda kv: -len(kv[1])):
        names = ", ".join(f"{r['arch']}x{r['shape']}" for r in rs[:6])
        more = f" (+{len(rs)-6} more)" if len(rs) > 6 else ""
        lines.append(f"* **{dom.replace('_s','')}-bound** ({len(rs)} cells):"
                     f" {names}{more}")
    lines.append("")
    lines.append(
        "Per-cell one-line reading: train cells are memory-bound "
        "(fusion-naive byte metric; real lever = flash kernel + remat "
        "policy, see §Perf cell 2); decode cells are collective-bound at "
        "baseline (KV-cache resharding — fixed 160x+ by flash-decode, "
        "§Perf cell 1) and memory-bound after; MoE cells are "
        "collective-bound (EP all-reduces + expert gather traffic — the "
        "natural next hillclimb target beyond the three assigned); "
        "SSM/hybrid decode cells are memory-bound on state r/w (intrinsic "
        "to S-independent decode).")
    return "\n".join(lines)


def main():
    text = EXP.read_text()
    text = re.sub(r"<!-- DRYRUN_SUMMARY -->",
                  lambda m: dryrun_summary(), text)
    text = re.sub(r"<!-- ROOFLINE_TABLE -->",
                  lambda m: roofline_table(), text)
    text = re.sub(r"<!-- ROOFLINE_ANALYSIS -->",
                  lambda m: analysis(), text)
    EXP.write_text(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
