"""Markdown link checker (stdlib-only) for the docs CI job.

Scans the given markdown files for inline links/images ``[text](target)``
and reference definitions ``[label]: target``, and verifies that every
*local* target resolves relative to the file that references it:

* ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI);
* pure-anchor targets (``#section``) must match a heading in the same
  file; ``path#anchor`` must match a heading in the target file
  (GitHub-style slugs: lowercase, spaces to dashes, punctuation dropped);
* everything else must exist on disk relative to the referencing file.

Exit 1 with one line per broken link; exit 0 silent-ish on success.

Usage: python tools/check_links.py README.md ROADMAP.md docs/*.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline [text](target) — stop at the first unescaped ')'; tolerate
# "(target "title")".  Images are the same syntax behind '!'.
INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\[[^\]]+\]:\s*(\S+)", re.MULTILINE)
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: strip markdown/punctuation, lowercase,
    spaces to dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    return {slugify(h) for h in HEADING.findall(path.read_text())}


def check_file(md: Path) -> list[str]:
    text = FENCE.sub("", md.read_text())   # links inside code fences are code
    targets = INLINE.findall(text) + REFDEF.findall(text)
    errors = []
    for t in targets:
        if t.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = t.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if path_part and not dest.exists():
            errors.append(f"{md}: broken link target '{t}' "
                          f"(no such file: {dest})")
            continue
        if anchor and dest.suffix == ".md":
            if slugify(anchor) not in anchors_of(dest):
                errors.append(f"{md}: broken anchor '{t}' "
                              f"(no heading '#{anchor}' in {dest.name})")
    return errors


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv]
    if not files:
        print("usage: python tools/check_links.py FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    errors = []
    for md in files:
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        errors.extend(check_file(md))
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        return 1
    print(f"check_links: {len(files)} files OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
