"""Markdown link checker (stdlib-only) for the docs CI job.

Scans the given markdown files for inline links/images ``[text](target)``
and reference definitions ``[label]: target``, and verifies that every
*local* target resolves relative to the file that references it:

* ``http(s)://`` / ``mailto:`` targets are skipped (no network in CI);
* pure-anchor targets (``#section``) must match a heading in the same
  file; ``path#anchor`` must match a heading in the target file
  (GitHub-style slugs: lowercase, spaces to dashes, punctuation dropped);
* everything else must exist on disk relative to the referencing file.

With ``--orphans ROOT.md DIR`` it additionally fails on orphaned docs
pages: every ``*.md`` under DIR must be transitively reachable from
ROOT.md by following local markdown links — a doc nobody links to is a
doc nobody reads, and CI stops it from rotting silently.

Exit 1 with one line per broken link / orphan; exit 0 silent-ish on
success.

Usage:
  python tools/check_links.py README.md ROADMAP.md docs/*.md
  python tools/check_links.py --orphans README.md docs docs/*.md
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# inline [text](target) — stop at the first unescaped ')'; tolerate
# "(target "title")".  Images are the same syntax behind '!'.
INLINE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\[[^\]]+\]:\s*(\S+)", re.MULTILINE)
HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: strip markdown/punctuation, lowercase,
    spaces to dashes."""
    text = re.sub(r"[`*_]", "", heading.strip())
    text = re.sub(r"[^\w\- ]", "", text.lower())
    return text.replace(" ", "-")


def anchors_of(path: Path) -> set[str]:
    return {slugify(h) for h in HEADING.findall(path.read_text())}


def local_md_targets(md: Path) -> set[Path]:
    """Resolved local ``*.md`` files ``md`` links to (anchors stripped,
    code fences ignored) — the edge set for the orphan walk."""
    text = FENCE.sub("", md.read_text())
    out = set()
    for t in INLINE.findall(text) + REFDEF.findall(text):
        if t.startswith(("http://", "https://", "mailto:")):
            continue
        path_part = t.partition("#")[0]
        if not path_part:
            continue
        dest = (md.parent / path_part).resolve()
        if dest.suffix == ".md" and dest.exists():
            out.add(dest)
    return out


def check_file(md: Path) -> list[str]:
    text = FENCE.sub("", md.read_text())   # links inside code fences are code
    targets = INLINE.findall(text) + REFDEF.findall(text)
    errors = []
    for t in targets:
        if t.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = t.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if path_part and not dest.exists():
            errors.append(f"{md}: broken link target '{t}' "
                          f"(no such file: {dest})")
            continue
        if anchor and dest.suffix == ".md":
            if slugify(anchor) not in anchors_of(dest):
                errors.append(f"{md}: broken anchor '{t}' "
                              f"(no heading '#{anchor}' in {dest.name})")
    return errors


def check_orphans(root: Path, docs_dir: Path) -> list[str]:
    """Every ``*.md`` under ``docs_dir`` must be transitively reachable
    from ``root`` by following local markdown links."""
    if not root.exists():
        return [f"{root}: orphan-check root not found"]
    if not docs_dir.is_dir():
        return [f"{docs_dir}: orphan-check directory not found"]
    reachable = {root.resolve()}
    frontier = [root.resolve()]
    while frontier:
        nxt = local_md_targets(frontier.pop())
        fresh = nxt - reachable
        reachable |= fresh
        frontier.extend(fresh)
    return [f"{page}: orphaned docs page (not reachable from {root} "
            "via local links)"
            for page in sorted(docs_dir.glob("**/*.md"))
            if page.resolve() not in reachable]


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(
        description="markdown link + orphan checker (stdlib-only)")
    ap.add_argument("files", nargs="+", type=Path, metavar="FILE.md",
                    help="markdown files whose links to verify")
    ap.add_argument("--orphans", nargs=2, type=Path,
                    metavar=("ROOT.md", "DIR"), default=None,
                    help="also fail on *.md under DIR not transitively "
                         "reachable from ROOT.md via local links")
    args = ap.parse_args(argv)
    errors = []
    for md in args.files:
        if not md.exists():
            errors.append(f"{md}: file not found")
            continue
        errors.extend(check_file(md))
    if args.orphans is not None:
        errors.extend(check_orphans(*args.orphans))
    for e in errors:
        print(e, file=sys.stderr)
    if errors:
        return 1
    extra = "" if args.orphans is None else " + orphan check"
    print(f"check_links: {len(args.files)} files OK{extra}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
