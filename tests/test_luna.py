"""Core LUNA arithmetic: exhaustive + property tests against the paper."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -e .[dev])")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import luna
from repro.core.luna import LunaMode


# ---------------------------------------------------------------------------
# Exact modes are bit-exact multipliers (exhaustive over all 4b pairs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", [LunaMode.CONVENTIONAL, LunaMode.DC,
                                  LunaMode.OPT_DC])
def test_exact_modes_exhaustive_4b(mode):
    w, y = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    got = luna.luna_product(jnp.asarray(w), jnp.asarray(y), bits=4, mode=mode)
    np.testing.assert_array_equal(np.asarray(got), w * y)


@pytest.mark.parametrize("bits", [4, 6, 8])
@given(data=st.data())
@settings(max_examples=30, deadline=None)
def test_exact_dc_property(bits, data):
    hi = (1 << bits) - 1
    w = data.draw(st.integers(0, hi))
    y = data.draw(st.integers(0, hi))
    got = luna.luna_product(jnp.int32(w), jnp.int32(y), bits=bits,
                            mode=LunaMode.DC)
    assert int(got) == w * y


# ---------------------------------------------------------------------------
# Approx modes: the paper's exact error semantics
# ---------------------------------------------------------------------------

def test_approx_dc_error_range_fig8():
    err = luna.error_table(LunaMode.APPROX_DC, bits=4)
    assert err.min() == 0 and err.max() == 45          # paper Fig 8: [0, 45]
    # error = W * y_lo exactly
    w, y = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    np.testing.assert_array_equal(err, w * (y & 3))


def test_approx_dc2_error_range_fig12():
    err = luna.error_table(LunaMode.APPROX_DC2, bits=4)
    assert err.min() == -15 and err.max() == 30        # paper Fig 12: [-15, 30]
    w, y = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    np.testing.assert_array_equal(err, w * ((y & 3) - 1))


def test_fig5_lsb_distribution():
    vals, probs, max_val = luna.lsb_product_distribution()
    assert max_val == 45
    assert probs[0] == pytest.approx(19 / 64)          # paper: 0.296
    assert probs.sum() == pytest.approx(1.0)


def test_fig5_impossible_values():
    """Paper: 17,19,23,25,29,31,32,34,35,37,38,40,41,43,44,46..63 unreachable."""
    imp = set(luna.impossible_lsb_products())
    paper = {17, 19, 23, 25, 29, 31, 32, 34, 35, 37, 38, 40, 41, 43, 44}
    paper |= set(range(46, 64))
    assert paper <= imp
    # all reachable ones really are products
    reachable = {w * y for w in range(16) for y in range(4)}
    assert imp == set(range(64)) - reachable


def test_fig6_hamming_optimal_is_zero():
    cands, hd = luna.hamming_distance_profile()
    assert int(np.argmin(hd)) == 0                     # paper: argmin at 0
    assert hd[0] == pytest.approx(0.275, abs=0.005)    # paper: 0.275


# ---------------------------------------------------------------------------
# Matmul semantics == summed element-wise semantics (the D&C commutes with
# contraction) — hypothesis over shapes and bit widths.
# ---------------------------------------------------------------------------

@given(m=st.integers(1, 5), k=st.integers(1, 9), n=st.integers(1, 5),
       mode=st.sampled_from(list(LunaMode)), bits=st.sampled_from([4, 8]),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_luna_matmul_matches_elementwise(m, k, n, mode, bits, seed):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 1 << bits, (m, k))
    w = rng.integers(0, 1 << bits, (k, n))
    got = np.asarray(luna.luna_matmul(jnp.asarray(y), jnp.asarray(w),
                                      bits=bits, mode=mode))
    ref = np.zeros((m, n), np.int64)
    for i in range(m):
        for j in range(n):
            prods = luna.luna_product(jnp.asarray(w[:, j]), jnp.asarray(y[i]),
                                      bits=bits, mode=mode)
            ref[i, j] = int(np.asarray(prods).sum())
    np.testing.assert_array_equal(got, ref)


def test_approx_dc2_colsum_identity():
    """ApproxD&C2's LSB term == colsum(W): the 'free bias' TPU mapping."""
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.integers(0, 16, (7, 33)))
    w = jnp.asarray(rng.integers(0, 16, (33, 5)))
    a2 = luna.luna_matmul(y, w, mode=LunaMode.APPROX_DC2)
    a0 = luna.luna_matmul(y, w, mode=LunaMode.APPROX_DC)
    np.testing.assert_array_equal(np.asarray(a2 - a0),
                                  np.broadcast_to(np.asarray(w).sum(0), a2.shape))


# ---------------------------------------------------------------------------
# Optimized table storage (Fig 3): 10 stored cells reconstruct the table
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", range(16))
def test_optimized_table_reconstruction(w):
    st_ = luna.optimized_table_storage(w, bits=4)
    assert st_["num_cells"] == 10                      # paper Fig 3
    assert luna.optimized_table_reconstruct(st_) == [0, w, 2 * w, 3 * w]
