"""Sharding rules + a miniature dry-run (subprocess, 16 fake devices)."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.parallel.sharding import param_spec


class FakeMesh:
    axis_names = ("data", "model")

    class _D:
        shape = (4, 2)
        size = 8
    devices = _D()


MESH = FakeMesh()


def test_param_rules_attention():
    assert param_spec("blocks/attn/wq", (8, 128, 256), MESH) == \
        P(None, "data", "model")
    assert param_spec("blocks/attn/wo", (8, 256, 128), MESH) == \
        P(None, "model", "data")


def test_param_rules_guard_indivisible():
    # 127 not divisible by 4 -> data axis dropped
    assert param_spec("blocks/attn/wq", (8, 127, 256), MESH) == \
        P(None, None, "model")


def test_param_rules_moe_experts():
    spec = param_spec("blocks/moe/w_gate", (8, 16, 128, 64), MESH)
    assert spec == P(None, "model", "data", None)
    spec = param_spec("blocks/moe/w_down", (8, 16, 64, 128), MESH)
    assert spec == P(None, "model", None, "data")


def test_param_rules_norms_replicated():
    assert param_spec("blocks/ln1", (8, 128), MESH) == P()
    assert param_spec("ln_f", (128,), MESH) == P()


def test_embed_vocab_parallel():
    assert param_spec("embed", (64000, 4096), MESH) == P("model", "data")
    assert param_spec("lm_head", (4096, 64000), MESH) == P("data", "model")


def test_cache_shardings_types():
    import jax.numpy as jnp
    from repro.models.attention import KVCache
    from repro.models.ssm import SSMCache
    from repro.parallel.sharding import cache_shardings
    mesh = make_host_mesh(model=1)
    # GQA stacked cache
    kv = KVCache(jax.ShapeDtypeStruct((4, 2, 64, 2, 16), jnp.bfloat16),
                 jax.ShapeDtypeStruct((4, 2, 64, 2, 16), jnp.bfloat16))
    ssm = SSMCache(jax.ShapeDtypeStruct((4, 2, 3, 128), jnp.bfloat16),
                   jax.ShapeDtypeStruct((4, 2, 8, 16, 16), jnp.float32))
    tree = ([kv], ssm)
    sh = cache_shardings(tree, mesh)
    assert sh[0][0].k.spec is not None
    assert sh[1].conv.spec is not None


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.models.registry import get_config, get_model, input_specs
    from repro.parallel import sharding as shd
    from repro.parallel.act_sharding import activation_sharding
    from repro.optim.adamw import AdamW, AdamWState
    from repro.train.train_step import make_train_step
    from repro.configs.base import ShapeConfig

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = get_config(%(arch)r).reduced(num_layers=2, d_model=256,
                                       num_heads=8, d_ff=512, head_dim=32)
    model = get_model(cfg)
    shape = ShapeConfig("t", 128, 8, %(kind)r)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = shd.param_shardings(params_shape, mesh)
    if %(kind)r == "train":
        opt = AdamW()
        step_fn, _ = make_train_step(cfg, opt, mesh)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        opt_sh = AdamWState(shd.scalar_sharding(mesh), p_sh, p_sh)
        batch_shape = input_specs(cfg, shape)
        b_sh = shd.batch_shardings(batch_shape, mesh)
        with mesh, activation_sharding(mesh):
            c = jax.jit(step_fn, in_shardings=(p_sh, opt_sh, b_sh)
                        ).lower(params_shape, opt_shape, batch_shape).compile()
    else:
        cache_shape = jax.eval_shape(lambda: model.init_cache(8, 128))
        c_sh = shd.cache_shardings(cache_shape, mesh)
        tok = jax.ShapeDtypeStruct((8, 1), jnp.int32)
        tok_sh = shd.batch_shardings({"token": tok}, mesh)["token"]
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh, activation_sharding(mesh):
            c = jax.jit(model.decode_step,
                        in_shardings=(p_sh, tok_sh, c_sh,
                                      shd.scalar_sharding(mesh))
                        ).lower(params_shape, tok, cache_shape, idx).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jaxlib: one dict per program
        ca = ca[0]
    print("COMPILED", ca.get("flops", 0) > 0)
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch,kind", [
    ("yi-9b", "train"), ("deepseek-v2-lite-16b", "train"),
    ("mamba2-1.3b", "train"), ("zamba2-1.2b", "decode"),
    ("yi-9b", "decode"),
])
def test_mini_dryrun_compiles(arch, kind):
    """The sharded step lowers+compiles on a 4x4 mesh for reduced configs."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = MINI_DRYRUN % {"arch": arch, "kind": kind}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=root, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "COMPILED True" in r.stdout


def test_dryrun_results_valid_if_present():
    """Every completed dry-run cell has coherent roofline terms."""
    import json
    from pathlib import Path
    res = Path(__file__).resolve().parents[1] / "results" / "dryrun"
    if not res.exists():
        pytest.skip("dry-run sweep not executed yet")
    n_ok = 0
    for p in res.glob("*.json"):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok":
            continue
        n_ok += 1
        assert rec["hlo_flops"] > 0, p.name
        assert rec["compute_s"] > 0, p.name
        assert rec["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert 0 <= rec["roofline_fraction"] <= 1.0001, p.name
    assert n_ok > 0
