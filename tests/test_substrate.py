"""Distribution substrate: checkpoint, data, optimizer, collectives, serving."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.data.synthetic import SyntheticLM
from repro.models.registry import get_config, get_model
from repro.optim.adamw import AdamW, cosine_schedule


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_synthetic_deterministic():
    d = SyntheticLM(128, 32, 4, seed=7)
    b1, b2 = d.batch_np(3), d.batch_np(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch_np(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    assert b1["labels"].shape == (4, 32)


def test_synthetic_learnable():
    """Bigram structure means labels correlate with chain(tokens)."""
    d = SyntheticLM(64, 64, 8, seed=0, noise=0.2)
    b = d.batch_np(0)
    pred = d.chain[b["tokens"]]
    agreement = (pred == b["labels"]).mean()
    assert agreement > 0.6


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.ones((4,)) * 5.0}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert np.isfinite(float(m["grad_norm"]))


def test_grad_clipping():
    opt = AdamW(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    huge = {"w": jnp.ones((3,)) * 1e6}
    _, state2, m = opt.update(huge, state, params)
    # post-clip m should be bounded: m = (1-b1) * clipped_grad
    assert float(jnp.abs(state2.m["w"]).max()) <= 0.1 * (1.0 + 1e-5)


def test_cosine_schedule_shape():
    sch = cosine_schedule(10, 100)
    assert float(sch(jnp.int32(0))) == 0.0
    assert float(sch(jnp.int32(10))) == pytest.approx(1.0)
    assert float(sch(jnp.int32(100))) == pytest.approx(0.1, abs=1e-5)


# ---------------------------------------------------------------------------
# checkpoint: atomic, latest, elastic
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    ck.save(5, tree, blocking=True)
    assert ck.latest_step() == 5
    shape = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         tree)
    out = ck.restore(5, shape)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.ones(2) * s}, blocking=True)
    assert ck.steps() == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_ignores_partial(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"x": jnp.ones(2)}, blocking=True)
    # simulate a crash mid-write: tmp dir without meta
    (tmp_path / "step_9.tmp").mkdir()
    (tmp_path / "step_7").mkdir()          # no meta.json -> incomplete
    assert ck.latest_step() == 1


# ---------------------------------------------------------------------------
# trainer: loss goes down; kill -9 restart resumes
# ---------------------------------------------------------------------------

TRAIN_SNIPPET = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    sys.path.insert(0, "src")
    import jax
    from repro.models.registry import get_config
    from repro.train.trainer import Trainer, TrainerConfig
    from repro.data.synthetic import SyntheticLM
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("luna-mlp")
    tcfg = TrainerConfig(total_steps=%(steps)d, ckpt_every=5, log_every=5,
                         ckpt_dir=%(dir)r, lr=3e-3, warmup=2)
    mesh = make_host_mesh(model=2)
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
    t = Trainer(cfg, tcfg, mesh)
    params, hist = t.run(data)
    print("HIST", ",".join(f"{h:.4f}" for h in hist))
""")


@pytest.mark.slow
def test_trainer_loss_decreases(tmp_path):
    code = TRAIN_SNIPPET % {"steps": 30, "dir": str(tmp_path / "ck")}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    hist_line = [ln for ln in r.stdout.splitlines()
                 if ln.startswith("HIST")][0]
    hist = [float(x) for x in hist_line[5:].split(",")]
    assert hist[-1] < hist[0] * 0.9, hist


@pytest.mark.slow
def test_trainer_restart_resumes(tmp_path):
    """Run 12 steps (ckpt@5,10), kill, rerun: must resume from step 10."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = TRAIN_SNIPPET % {"steps": 12, "dir": str(tmp_path / "ck")}
    r1 = subprocess.run([sys.executable, "-c", code], capture_output=True,
                        text=True, cwd=root, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    code2 = TRAIN_SNIPPET % {"steps": 20, "dir": str(tmp_path / "ck")}
    r2 = subprocess.run([sys.executable, "-c", code2], capture_output=True,
                        text=True, cwd=root, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 12" in r2.stdout, r2.stdout
    hist = [ln for ln in r2.stdout.splitlines()
            if ln.startswith("HIST")][0]
    # resumed run trains only the remaining 8 steps
    assert len(hist[5:].split(",")) == 8


@pytest.mark.slow
def test_elastic_restore_different_device_count(tmp_path):
    """Checkpoint written on 4 devices restores onto 2 (elastic reshard)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = TRAIN_SNIPPET % {"steps": 6, "dir": str(tmp_path / "ck")}
    r1 = subprocess.run([sys.executable, "-c", code], capture_output=True,
                        text=True, cwd=root, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    code2 = (TRAIN_SNIPPET % {"steps": 10, "dir": str(tmp_path / "ck")}
             ).replace("device_count=4", "device_count=2"
                       ).replace("model=2", "model=1")
    r2 = subprocess.run([sys.executable, "-c", code2], capture_output=True,
                        text=True, cwd=root, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "resumed from step 6" in r2.stdout


# ---------------------------------------------------------------------------
# collectives: compressed all-reduce + error feedback
# ---------------------------------------------------------------------------

def test_compress_roundtrip_small_error():
    from repro.parallel.collectives import compress_grads_int8
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    gc = compress_grads_int8(g)
    rel = (np.abs(np.asarray(gc["w"] - g["w"])).max()
           / np.abs(np.asarray(g["w"])).max())
    assert rel < 0.02    # int8: ~1/127 relative error


def test_error_feedback_unbiased():
    """Error feedback: mean of compressed updates -> mean of true updates."""
    from repro.parallel.collectives import ErrorFeedback
    rng = np.random.default_rng(1)
    ef = ErrorFeedback()
    true_sum = np.zeros((16,), np.float32)
    comp_sum = np.zeros((16,), np.float32)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=16).astype(np.float32))}
        true_sum += np.asarray(g["w"])
        comp_sum += np.asarray(ef.compress(g)["w"])
    # cumulative compressed mass tracks the true mass (residual is bounded)
    np.testing.assert_allclose(comp_sum, true_sum, atol=0.05)


def test_quantized_psum_multidevice():
    """shard_map int8 psum vs exact psum (subprocess with 8 host devices)."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.parallel.collectives import quantized_psum
        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(8, 32)).astype(np.float32))
        def f(x):
            return quantized_psum(x, "data")
        got = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                        check_rep=False)(x)
        ref = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
        rel = float(jnp.abs(got - ref).max() / jnp.abs(ref).max())
        assert rel < 0.03, rel
        print("OK", rel)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=root, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_pipeline_matches_sequential():
    """GPipe-over-pods == running stages sequentially (2 'pods')."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = textwrap.dedent("""
        import os, sys
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import pipeline_apply
        mesh = jax.make_mesh((2,), ("pod",))
        rng = np.random.default_rng(0)
        W = jnp.asarray(rng.normal(size=(2, 16, 16)).astype(np.float32)) * 0.3
        xs = jnp.asarray(rng.normal(size=(4, 3, 16)).astype(np.float32))
        def stage(w, x):
            return jnp.tanh(x @ w)
        got = pipeline_apply(stage, W, xs, mesh=mesh)
        ref = jnp.stack([stage(W[1], stage(W[0], x)) for x in xs])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=root, timeout=300)
    assert r.returncode == 0, (r.stderr[-2000:], r.stdout[-500:])
    assert "OK" in r.stdout


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_engine_serves_batched_requests():
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Engine, Request
    cfg = get_config("yi-9b").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params, EngineConfig(max_batch=4, max_seq=64))
    reqs = [Request(rid=i, prompt=[1 + i, 2, 3], max_new=5)
            for i in range(6)]   # 6 requests > 4 slots: tests slot reuse
    stats = eng.serve(reqs)
    assert stats["done"]
    for r in reqs:
        assert len(r.out) == 5
        assert all(0 <= t < cfg.vocab_size for t in r.out)


def test_engine_decode_consistency():
    """Engine slab decode == single-request decode for the same prompt."""
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Engine, Request
    cfg = get_config("yi-9b").reduced(dtype="float32", attn_impl="full")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=32))
    r1 = Request(rid=0, prompt=[5, 6, 7], max_new=4)
    eng.serve([r1])
    eng2 = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=32))
    r2 = Request(rid=1, prompt=[5, 6, 7], max_new=4)
    eng2.serve([r2])
    assert r1.out == r2.out
