"""Background serve loop: threaded streaming pinned to the sync path.

The load-bearing pins:
  * loop-mode token streams (``start()`` + ``submit()`` +
    ``tokens()``-from-client-threads) are BYTE-IDENTICAL to the
    synchronous ``serve()`` path, for a mixed-family batch (attention,
    ssm, hybrid);
  * ``submit()`` is thread-safe: concurrent submits from many threads all
    finish with exactly the solo-reference output;
  * ``cancel()`` racing the final token never deadlocks and always
    terminates the stream;
  * ``stop(drain=True)`` finishes every in-flight request;
    ``stop(drain=False)`` leaves resumable state behind;
  * the injected clock is the single time base: a virtual clock makes
    deadline-miss accounting deterministic, and ``preempt()`` (cancel +
    requeue through the exact-accounting teardown) is greedy
    token-identical to an unpreempted run.
"""
import threading

import jax
import numpy as np
import pytest

from repro.models.registry import get_config, get_model
from repro.serve.config import EngineConfig
from repro.serve.engine import Engine, Request


class VirtualClock:
    """Hand-advanced monotonic clock (mirrors the load harness's)."""

    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now

    def advance(self, dt):
        self.now += dt


def _setup(arch="yi-9b", **over):
    cfg = get_config(arch).reduced(dtype="float32", attn_impl="full", **over)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, params


def _prompts(cfg, lens=(3, 9, 5, 12), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).tolist() for n in lens]


def _consume_threaded(handles, timeout=120):
    """Drain every handle's token stream on its own client thread."""
    outs = [None] * len(handles)

    def consume(i):
        outs[i] = list(handles[i].tokens())

    threads = [threading.Thread(target=consume, args=(i,), daemon=True)
               for i in range(len(handles))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout)
    assert all(not t.is_alive() for t in threads), "stream consumer hung"
    return outs


FAMILY_KNOBS = {
    "yi-9b": dict(paged=True, block_size=8),
    "mamba2-1.3b": dict(),
    "zamba2-1.2b": dict(paged=True, block_size=8),
}


@pytest.mark.parametrize("arch", sorted(FAMILY_KNOBS))
def test_loop_stream_identical_to_sync_mixed_family(arch):
    """Acceptance pin: background-loop token streams are byte-identical to
    the synchronous serve() path, for a mixed-length batch on every
    family (attention/paged, ssm, hybrid split-substrate)."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg)
    knobs = dict(max_batch=2, max_seq=48, **FAMILY_KNOBS[arch])

    sync = Engine(cfg, params, EngineConfig(**knobs))
    sync_reqs = [Request(rid=i, prompt=list(p), max_new=5)
                 for i, p in enumerate(prompts)]
    assert sync.serve(sync_reqs)["done"]
    ref = [list(r.out) for r in sync_reqs]

    loop = Engine(cfg, params, EngineConfig(**knobs)).start()
    try:
        loop_reqs = [Request(rid=i, prompt=list(p), max_new=5)
                     for i, p in enumerate(prompts)]
        handles = [loop.submit(r) for r in loop_reqs]
        outs = _consume_threaded(handles)
    finally:
        assert loop.stop(timeout=120)
    assert outs == ref
    assert [r.out for r in loop_reqs] == ref


def test_concurrent_submit_from_many_threads():
    """submit() is safe from concurrent client threads: every request
    finishes and matches its solo-reference output (2 slots, 8 requests
    from 4 threads — forces queueing through the loop-mode scheduler
    fallback)."""
    cfg, params = _setup()
    prompts = _prompts(cfg, lens=(3, 9, 5, 12, 4, 7, 6, 10))
    refs = []
    for i, p in enumerate(prompts):
        eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
        req = Request(rid=i, prompt=list(p), max_new=4)
        assert eng.serve([req])["done"]
        refs.append(list(req.out))

    loop = Engine(cfg, params,
                  EngineConfig(max_batch=2, max_seq=48)).start()
    reqs = [Request(rid=i, prompt=list(p), max_new=4)
            for i, p in enumerate(prompts)]
    outs = [None] * len(reqs)
    try:
        def client(idx):
            for i in range(idx, len(reqs), 4):
                h = loop.submit(reqs[i])
                outs[i] = list(h.tokens())

        threads = [threading.Thread(target=client, args=(k,), daemon=True)
                   for k in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(180)
        assert all(not t.is_alive() for t in threads), "client thread hung"
    finally:
        assert loop.stop(timeout=120)
    assert outs == refs


def test_tokens_blocks_on_queue_while_loop_runs():
    """A tokens() consumer never ticks the engine itself in loop mode: the
    stream completes while the caller only blocks, and equals req.out."""
    cfg, params = _setup()
    loop = Engine(cfg, params,
                  EngineConfig(max_batch=2, max_seq=48)).start()
    try:
        req = Request(rid=0, prompt=_prompts(cfg)[1], max_new=6)
        handle = loop.submit(req)
        ticks_before = loop.metrics.ticks
        stream = list(handle.tokens())     # this thread never calls step()
        assert loop.metrics.ticks > ticks_before
        assert stream == req.out and len(stream) == 6 and req.done
    finally:
        assert loop.stop(timeout=120)


def test_cancel_races_final_token():
    """cancel() fired from another thread mid-stream: the generator always
    terminates (token count <= max_new), nothing deadlocks, and the
    request ends done — whether the cancel won or the final token did."""
    cfg, params = _setup()
    loop = Engine(cfg, params,
                  EngineConfig(max_batch=2, max_seq=48)).start()
    try:
        for attempt, cancel_after in enumerate((1, 2, 3)):
            req = Request(rid=attempt, prompt=_prompts(cfg)[3], max_new=8)
            handle = loop.submit(req)
            got = []
            canceller = None
            for tok in handle.tokens():
                got.append(tok)
                if len(got) == cancel_after:
                    canceller = threading.Thread(target=handle.cancel,
                                                 daemon=True)
                    canceller.start()
            if canceller is not None:
                canceller.join(60)
                assert not canceller.is_alive()
            assert req.done
            assert cancel_after <= len(got) <= 8
            assert got == req.out[:len(got)]
    finally:
        assert loop.stop(timeout=120)


def test_stop_drains_inflight_requests():
    """stop(drain=True) keeps ticking until every queued + active request
    finished — no submitted token is lost."""
    cfg, params = _setup()
    loop = Engine(cfg, params,
                  EngineConfig(max_batch=2, max_seq=48)).start()
    reqs = [Request(rid=i, prompt=list(p), max_new=4)
            for i, p in enumerate(_prompts(cfg))]
    handles = [loop.submit(r) for r in reqs]
    assert loop.stop(drain=True, timeout=180)
    assert not loop.running and loop.idle
    assert all(r.done and len(r.out) == 4 for r in reqs)
    # streams subscribed after the stop still replay the full backlog
    assert [list(h.tokens()) for h in handles] == [r.out for r in reqs]


def test_stop_without_drain_is_resumable():
    """stop(drain=False) exits at a tick boundary; the survivors stay
    queued/active and a sync serve() finishes them with the exact
    reference output (state is never torn down off-thread)."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    ref_eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    ref = Request(rid=3, prompt=list(prompts[3]), max_new=6)
    assert ref_eng.serve([ref])["done"]

    loop = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    req = Request(rid=3, prompt=list(prompts[3]), max_new=6)
    loop.serve([req], max_ticks=0)          # enqueue without ticking
    loop.start()
    assert loop.stop(drain=False, timeout=120)
    assert loop.serve([])["done"] or req.done   # drain the survivor
    assert req.done and req.out == ref.out


def test_virtual_clock_deadline_accounting():
    """The injected clock is the single time base: deadlines stamped in
    virtual seconds account hits/misses deterministically."""
    cfg, params = _setup()
    vc = VirtualClock()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48),
                 clock=vc)
    prompts = _prompts(cfg)
    hit = Request(rid=0, prompt=prompts[0], max_new=2, deadline=1e9)
    miss = Request(rid=1, prompt=prompts[1], max_new=2, deadline=0.5)
    vc.advance(1.0)                  # past miss's deadline before admission
    assert eng.serve([hit, miss])["done"]
    assert eng.metrics.deadline_hits == 1
    assert eng.metrics.deadline_misses == 1
    assert hit.token_ts and hit.token_ts[0] == vc.now == 1.0
    assert hit.submit_ts == 1.0      # stamped on the same clock


def test_preempt_requeue_is_greedy_identical():
    """preempt() mid-decode (slot + reservation released through the
    cancel-path accounting, emitted tokens folded into the prompt,
    request requeued) continues the greedy stream token-identically to a
    run that was never preempted."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    knobs = EngineConfig(max_batch=2, max_seq=48, paged=True, block_size=8)

    ref_eng = Engine(cfg, params, knobs)
    ref = Request(rid=7, prompt=list(prompts[1]), max_new=8)
    assert ref_eng.serve([ref])["done"]

    eng = Engine(cfg, params, knobs)
    req = Request(rid=7, prompt=list(prompts[1]), max_new=8)
    eng.serve([req], max_ticks=0)
    for _ in range(4):
        eng.step()
    assert 0 < len(req.out) < 8 and not req.done
    free_before = eng.backend.free_capacity
    assert eng.preempt(req)
    assert eng.backend.free_capacity > free_before  # blocks really freed
    assert eng.metrics.preemptions == 1
    while not req.done:
        eng.step()
    assert req.out == ref.out
    # preempting a non-active (queued/finished) request is a no-op
    assert not eng.preempt(req)


def test_submit_backpressure_queues_under_loop():
    """Loop-mode contract shift: a backpressured submit() returns a falsy
    handle but the request is QUEUED — the loop admits it when capacity
    frees and the stream still completes."""
    cfg, params = _setup()
    loop = Engine(cfg, params,
                  EngineConfig(max_batch=1, max_seq=48)).start()
    try:
        reqs = [Request(rid=i, prompt=list(p), max_new=4)
                for i, p in enumerate(_prompts(cfg, lens=(6, 6, 6)))]
        handles = [loop.submit(r) for r in reqs]
        assert not all(handles), "3 requests on 1 slot must backpressure"
        outs = _consume_threaded(handles)
        assert all(len(o) == 4 for o in outs)
        assert outs == [r.out for r in reqs]
    finally:
        assert loop.stop(timeout=120)
