"""Continuous-batching engine: mixed-depth correctness + sampling.

The load-bearing tests:
  * requests with DIFFERENT prompt lengths served concurrently on one slab
    must emit token-identical output to serving each request alone — for
    greedy AND sampled modes (per-request PRNG streams);
  * the paged-block KV cache and chunked prefill must be token-identical to
    the dense-slab reference oracle in every combination.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config, get_model
from repro.serve.config import EngineConfig
from repro.serve.engine import Engine, Request

from repro.serve.paged import BlockAllocator, blocks_needed
from repro.serve.sampling import SamplingConfig, sample


def _engine(cfg, params, **knobs):
    """Engine built from knob kwargs (the legacy shim is gone: every
    construction goes through an explicit EngineConfig)."""
    return Engine(cfg, params, EngineConfig(**knobs))


MIXED_LENS = (3, 9, 5, 17, 2)


def _setup(arch="yi-9b", **over):
    cfg = get_config(arch).reduced(dtype="float32", attn_impl="full", **over)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, params


def _prompts(cfg, lens=MIXED_LENS):
    rng = np.random.default_rng(0)
    return [rng.integers(1, cfg.vocab_size, n).tolist() for n in lens]


def _sequential_reference(cfg, params, prompts, max_new, max_seq=48,
                          sampling=None, seed=0, rids=None):
    """Each request served alone — same rid as in the batched run, so the
    per-request sampling streams line up."""
    outs = []
    for i, p in enumerate(prompts):
        eng = _engine(cfg, params, max_batch=1, max_seq=max_seq,
                     sampling=sampling, seed=seed)
        req = Request(rid=rids[i] if rids else i, prompt=p, max_new=max_new)
        assert eng.serve([req])["done"]
        outs.append(req.out)
    return outs


def test_mixed_length_batch_matches_sequential():
    """5 mixed-length requests on a 3-slot slab (forces slot reuse and a
    mixed-depth slab) == each request served alone."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    eng = _engine(cfg, params, max_batch=3, max_seq=48)
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    stats = eng.serve(reqs)
    assert stats["done"]
    ref = _sequential_reference(cfg, params, prompts, max_new=6)
    for i, (req, expect) in enumerate(zip(reqs, ref)):
        assert req.out == expect, (i, len(prompts[i]), req.out, expect)


def test_two_requests_different_lengths_concurrent():
    """The acceptance-criteria shape: two concurrent requests of different
    prompt lengths, token-identical to one-at-a-time serving."""
    cfg, params = _setup()
    p_short, p_long = [5, 6, 7], [9, 8, 7, 6, 5, 4, 3, 2, 1, 10, 11]
    eng = _engine(cfg, params, max_batch=2, max_seq=48)
    reqs = [Request(rid=0, prompt=p_short, max_new=5),
            Request(rid=1, prompt=p_long, max_new=5)]
    assert eng.serve(reqs)["done"]
    ref = _sequential_reference(cfg, params, [p_short, p_long], max_new=5)
    assert reqs[0].out == ref[0]
    assert reqs[1].out == ref[1]


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b"])
def test_mixed_length_batch_recurrent_families(arch):
    """SSM/hybrid slabs (padded length buckets masked out of the recurrent
    state, position-free or mixed caches) also match the sequential
    reference."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg, lens=(4, 7, 4))
    eng = _engine(cfg, params, max_batch=2, max_seq=48)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    assert eng.serve(reqs)["done"]
    ref = _sequential_reference(cfg, params, prompts, max_new=4)
    for req, expect in zip(reqs, ref):
        assert req.out == expect


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b"])
def test_recurrent_chunked_prefill_matches_whole_prompt(arch):
    """The tentpole acceptance pin: for the recurrent families, bucketed
    batched prefill AND chunked prefill (state-continuing masked SSD scan)
    are token-identical to the exact-length whole-prompt dense oracle; the
    hybrid additionally runs its attention leaves in the paged block pool
    (split substrate) with dense SSM state."""
    cfg, params = _setup(arch)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (31, 4, 12)]          # 31 == max_seq - 1
    modes = {
        "whole_exact": {"prefill_bucket": 1},  # unpadded whole-prompt oracle
        "bucketed": {},                        # padded 16-bucket batches
        "chunked": {"prefill_chunk": 8},
    }
    if arch == "zamba2-1.2b":
        modes["paged_chunked"] = {"prefill_chunk": 8, "paged": True,
                                  "block_size": 8}
    outs = {}
    for mode, kw in modes.items():
        eng = _engine(cfg, params, max_batch=2, max_seq=32, **kw)
        reqs = [Request(rid=i, prompt=p, max_new=5)
                for i, p in enumerate(prompts)]
        stats = eng.serve(reqs)
        assert stats["done"], (arch, mode)
        if "chunk" in mode:
            assert stats["prefill_chunks"] >= 4     # 31 tokens / 8-chunks
        outs[mode] = [r.out for r in reqs]
    for mode in modes:
        assert outs[mode] == outs["whole_exact"], (arch, mode)


def test_hybrid_paged_matches_dense_mixed_lengths():
    """Split substrate: the hybrid with paged attention pools + dense SSM
    state is token-identical to the all-dense hybrid on a mixed-length
    workload with slot reuse."""
    cfg, params = _setup("zamba2-1.2b")
    prompts = _prompts(cfg)
    outs = {}
    for paged in (False, True):
        eng = _engine(cfg, params, max_batch=3, max_seq=48, paged=paged,
                     block_size=8)
        reqs = [Request(rid=i, prompt=p, max_new=6)
                for i, p in enumerate(prompts)]
        assert eng.serve(reqs)["done"]
        outs[paged] = [r.out for r in reqs]
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# paged-block KV cache + chunked prefill vs the dense reference oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
def test_paged_matches_dense_mixed_lengths(arch):
    """The tentpole acceptance criterion: the paged engine is
    token-identical to the dense-slab engine on a mixed-length greedy
    workload with slot reuse (yi-9b: GQA pools; deepseek-v2-lite: MLA
    compressed pools)."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg)
    outs = {}
    for paged in (False, True):
        eng = _engine(cfg, params, max_batch=3, max_seq=48, paged=paged,
                     block_size=8)
        reqs = [Request(rid=i, prompt=p, max_new=6)
                for i, p in enumerate(prompts)]
        assert eng.serve(reqs)["done"]
        outs[paged] = [r.out for r in reqs]
    assert outs[True] == outs[False]


def test_chunked_prefill_matches_whole_prompt():
    """A max_seq-1 prompt admitted in prefill_chunk pieces (dense and
    paged) == the same prompt prefilled whole."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, n).tolist()
               for n in (31, 4, 12)]          # 31 == max_seq - 1
    outs = {}
    for mode, kw in {
        "whole": {},
        "chunked": {"prefill_chunk": 8},
        "paged_chunked": {"prefill_chunk": 8, "paged": True,
                          "block_size": 8},
    }.items():
        eng = _engine(cfg, params, max_batch=2, max_seq=32, **kw)
        reqs = [Request(rid=i, prompt=p, max_new=5)
                for i, p in enumerate(prompts)]
        stats = eng.serve(reqs)
        assert stats["done"]
        if mode != "whole":
            assert stats["prefill_chunks"] >= 4     # 31 tokens / 8-chunks
        outs[mode] = [r.out for r in reqs]
    assert outs["chunked"] == outs["whole"]
    assert outs["paged_chunked"] == outs["whole"]


def test_chunked_prefill_interleaves_decode():
    """While a long admission is mid-flight, every engine tick still
    advances active decodes — one token per tick, i.e. a tick never waits
    on more than one chunk of prefill work."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    eng = _engine(cfg, params, max_batch=2, max_seq=48, prefill_chunk=8)
    short = Request(rid=0, prompt=[5, 6, 7], max_new=30)
    assert eng.submit(short)
    long = Request(rid=1,
                   prompt=rng.integers(1, cfg.vocab_size, 20).tolist(),
                   max_new=4)
    assert eng.submit(long)                  # starts a chunked admission
    assert long.out == []                    # no prefill ran yet
    ticks = 0
    while not long.out:                      # 20 tokens / 8 -> 3 pieces
        emitted = len(short.out)
        eng.step()
        ticks += 1
        assert len(short.out) == emitted + 1, \
            f"decode stalled during chunked admission at tick {ticks}"
    assert ticks == 3
    # and the interleaved result still matches the sequential reference
    while eng.active:
        eng.step()
    ref = _sequential_reference(cfg, params, [long.prompt], max_new=4,
                                rids=[1])
    assert long.out == ref[0]


def test_max_new_one_emits_exactly_one_token():
    """Bugfix pin: max_new=1 must emit exactly the prefill-sampled token
    (the v2 engine appended a second from the next decode tick), and the
    slot must be free for the next request immediately."""
    cfg, params = _setup()
    for kw in ({}, {"paged": True, "block_size": 8}):
        eng = _engine(cfg, params, max_batch=1, max_seq=48, **kw)
        req = Request(rid=0, prompt=[3, 1, 4], max_new=1)
        stats = eng.serve([req])
        assert stats["done"]
        assert len(req.out) == 1, req.out
        assert eng.slots == [None] and not eng.active
        if eng.paged:
            assert eng.allocator.used_blocks == 0
        assert eng.submit(Request(rid=1, prompt=[1, 5], max_new=1))


def test_prompt_at_max_seq_boundary():
    """Prompt length exactly max_seq - 1 admits, emits, and terminates on
    the position cap without touching columns past the cache end."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    prompt = rng.integers(1, cfg.vocab_size, 31).tolist()
    for kw in ({}, {"paged": True, "block_size": 8}):
        eng = _engine(cfg, params, max_batch=1, max_seq=32, **kw)
        req = Request(rid=0, prompt=prompt, max_new=8)
        stats = eng.serve([req])
        assert stats["done"]
        assert len(req.out) == 2             # prefill token + 1 decode step
    with pytest.raises(ValueError):          # max_seq-long prompt: rejected
        _engine(cfg, params, max_batch=1, max_seq=32).submit(
            Request(rid=1, prompt=rng.integers(1, 9, 32).tolist()))


def test_slot_reuse_no_stale_state():
    """A slot freed by a long request must not leak positions/blocks into
    its next (shorter) tenant: run long-then-short through a 1-slot engine
    and compare against a fresh engine."""
    cfg, params = _setup()
    rng = np.random.default_rng(6)
    long_p = rng.integers(1, cfg.vocab_size, 20).tolist()
    short_p = rng.integers(1, cfg.vocab_size, 4).tolist()
    for kw in ({}, {"paged": True, "block_size": 8}):
        eng = _engine(cfg, params, max_batch=1, max_seq=48, **kw)
        first = Request(rid=0, prompt=long_p, max_new=6)
        assert eng.serve([first])["done"]
        second = Request(rid=1, prompt=short_p, max_new=6)
        assert eng.serve([second])["done"]
        ref = _sequential_reference(cfg, params, [short_p], max_new=6,
                                    rids=[1])
        assert second.out == ref[0], kw


def test_paged_backpressure_full_pool():
    """With a pool that fits ~one request, pending requests wait for blocks
    and still run to completion; submit() reports False meanwhile."""
    cfg, params = _setup()
    prompts = _prompts(cfg, lens=(5, 4, 6))
    eng = _engine(cfg, params, max_batch=3, max_seq=48, paged=True,
                 block_size=8, num_blocks=3)      # 2 usable blocks
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    assert eng.submit(reqs[0])
    assert not eng.submit(reqs[1])           # slots free, blocks are not
    stats = eng.serve(reqs[1:])
    assert stats["done"] and reqs[0].done
    ref = _sequential_reference(cfg, params, prompts, max_new=6)
    assert [r.out for r in reqs] == ref
    assert eng.allocator.used_blocks == 0    # everything returned


def test_submit_on_full_engine():
    cfg, params = _setup()
    eng = _engine(cfg, params, max_batch=1, max_seq=48)
    assert eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=8))
    assert not eng.submit(Request(rid=1, prompt=[4, 5], max_new=2))


def test_paged_rejects_ssm_and_oversized():
    """ssm has no KV leaves to page -> clear construction-time ValueError
    (chunked prefill, by contrast, is now supported for every served
    family); oversized block demands are rejected at submit."""
    cfg, params = _setup("mamba2-1.3b")
    with pytest.raises(ValueError, match="paged"):
        _engine(cfg, params, max_batch=1, max_seq=32, paged=True)
    _engine(cfg, params, max_batch=1, max_seq=32, prefill_chunk=8)  # ok now
    cfg2, params2 = _setup()
    eng = _engine(cfg2, params2, max_batch=1, max_seq=64, paged=True,
                 block_size=8, num_blocks=4)
    with pytest.raises(ValueError):          # needs more blocks than exist
        eng.submit(Request(rid=0, prompt=list(range(1, 40)), max_new=16))


def test_block_allocator():
    a = BlockAllocator(5, 4)
    assert a.free_blocks == 4                # block 0 reserved
    got = a.alloc(3)
    assert len(got) == 3 and 0 not in got
    assert a.alloc(2) is None and a.free_blocks == 1
    a.release(got)
    assert a.free_blocks == 4 and a.used_blocks == 0
    assert blocks_needed(5, 6, 48, 8) == 2   # ceil(11 / 8)
    assert blocks_needed(31, 8, 32, 8) == 4  # capped at max_seq


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,kw", [
    ("temperature", {"temperature": 0.7}),
    ("top_k", {"top_k": 8, "temperature": 0.7}),
])
def test_sampled_mixed_batch_matches_sequential(mode, kw):
    """Bugfix pin: per-request PRNG streams make sampled output independent
    of slot index and co-tenants — mixed-batch == sequential holds for the
    sampled modes, not just greedy."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    sc = SamplingConfig(mode=mode, **kw)
    eng = _engine(cfg, params, max_batch=3, max_seq=48, sampling=sc, seed=11)
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    assert eng.serve(reqs)["done"]
    ref = _sequential_reference(cfg, params, prompts, max_new=6,
                                sampling=sc, seed=11)
    for i, (req, expect) in enumerate(zip(reqs, ref)):
        assert req.out == expect, (mode, i, req.out, expect)


def test_sampling_determinism_fixed_key():
    """Same seed -> identical sampled streams; different seed -> (almost
    surely) different ones."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    sc = SamplingConfig(mode="top_k", top_k=8, temperature=0.7)

    def run(seed):
        eng = _engine(cfg, params, max_batch=3, max_seq=48,
                     sampling=sc, seed=seed)
        reqs = [Request(rid=i, prompt=p, max_new=8)
                for i, p in enumerate(prompts)]
        assert eng.serve(reqs)["done"]
        return [r.out for r in reqs]

    assert run(42) == run(42)
    assert run(42) != run(7)


def test_sample_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0], [3.0, 0.0, 0.0, 0.0]])
    key = jax.random.PRNGKey(0)
    greedy = sample(logits, key, SamplingConfig())
    np.testing.assert_array_equal(np.asarray(greedy), [1, 0])
    # top_k=1 == greedy regardless of key/temperature
    top1 = sample(logits, key, SamplingConfig(mode="top_k", top_k=1,
                                              temperature=3.0))
    np.testing.assert_array_equal(np.asarray(top1), [1, 0])
    # top_k restricts support
    for s in range(5):
        t = sample(logits, jax.random.PRNGKey(s),
                   SamplingConfig(mode="top_k", top_k=2, temperature=1.0))
        assert int(t[0]) in (1, 2) and int(t[1]) in (0, 1, 2, 3)
    with pytest.raises(ValueError):
        SamplingConfig(mode="nucleus")
    with pytest.raises(ValueError):
        SamplingConfig(mode="temperature", temperature=0.0)
    with pytest.raises(ValueError):
        SamplingConfig(mode="top_k", top_k=4, temperature=0.0)


def test_sample_per_request_stream_slot_invariant():
    """The same (rid, step) draws the same token wherever the row sits in
    the batch; different rids draw independent streams."""
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    logits_row = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    cfg = SamplingConfig(mode="temperature", temperature=1.0)
    batch = jnp.stack([logits_row, logits_row + 1.0, logits_row])
    t1 = sample(batch, key, cfg, rids=jnp.asarray([7, 1, 2]),
                steps=jnp.asarray([3, 0, 0]))
    t2 = sample(batch[::-1], key, cfg, rids=jnp.asarray([2, 1, 7]),
                steps=jnp.asarray([0, 0, 3]))
    assert int(t1[0]) == int(t2[2])          # rid 7 step 3, slots 0 vs 2
    assert int(t1[2]) == int(t2[0])          # rid 2 step 0
    draws = {int(sample(batch, key, cfg, rids=jnp.asarray([7, 1, 2]),
                        steps=jnp.asarray([s, 0, 0]))[0])
             for s in range(16)}
    assert len(draws) > 1                    # steps advance the stream


# ---------------------------------------------------------------------------
# metrics / bookkeeping
# ---------------------------------------------------------------------------

def test_engine_metrics_and_bucketing():
    """Bucketed prefill: one jit call admits same-bucket prompts together;
    metrics account every token."""
    cfg, params = _setup()
    prompts = _prompts(cfg, lens=(3, 5, 4, 6))   # all in one 16-bucket
    eng = _engine(cfg, params, max_batch=4, max_seq=48, prefill_bucket=16)
    reqs = [Request(rid=i, prompt=p, max_new=3)
            for i, p in enumerate(prompts)]
    stats = eng.serve(reqs)
    assert stats["done"]
    assert stats["prefill_calls"] == 1           # one bucket, one jit call
    assert stats["prefill_tokens"] == sum(len(p) for p in prompts)
    # every emitted token is accounted: 1 from prefill + rest from decode
    assert stats["decode_tokens"] == sum(len(r.out) - 1 for r in reqs)
    assert 0.0 < stats["occupancy"] <= 1.0
    assert stats["decode_tok_s"] > 0


def test_engine_rejects_oversized_prompt():
    cfg, params = _setup()
    eng = _engine(cfg, params, max_batch=2, max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=list(range(1, 17)), max_new=2))
    with pytest.raises(ValueError):
        _engine(cfg, params, max_batch=2, max_seq=16, prefill_bucket=0)


def test_engine_reuse_reports_per_call_stats():
    """serve() stats cover that call only; Engine.metrics keeps the
    lifetime totals."""
    cfg, params = _setup()
    eng = _engine(cfg, params, max_batch=2, max_seq=48)
    p = _prompts(cfg, lens=(3, 5))
    s1 = eng.serve([Request(rid=0, prompt=p[0], max_new=4),
                    Request(rid=1, prompt=p[1], max_new=4)])
    s2 = eng.serve([Request(rid=2, prompt=p[0], max_new=4)])
    assert s1["done"] and s2["done"]
    assert s2["decode_tokens"] == 3          # 4 emitted - 1 from prefill
    assert s2["prefill_tokens"] == len(p[0])
    assert s2["ticks"] < s1["ticks"] + s2["ticks"]
    assert eng.metrics.decode_tokens == \
        s1["decode_tokens"] + s2["decode_tokens"]
