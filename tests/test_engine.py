"""Continuous-batching engine: mixed-depth correctness + sampling.

The load-bearing test: requests with DIFFERENT prompt lengths served
concurrently on one slab must emit token-identical output to serving each
request alone (greedy) — this pins the per-slot decode-position fix (the
seed engine decoded every row at the single shared ``positions.max()``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config, get_model
from repro.serve.engine import Engine, Request
from repro.serve.sampling import SamplingConfig, sample

MIXED_LENS = (3, 9, 5, 17, 2)


def _setup(arch="yi-9b", **over):
    cfg = get_config(arch).reduced(dtype="float32", attn_impl="full", **over)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, params


def _prompts(cfg, lens=MIXED_LENS):
    rng = np.random.default_rng(0)
    return [rng.integers(1, cfg.vocab_size, n).tolist() for n in lens]


def _sequential_reference(cfg, params, prompts, max_new, max_seq=48):
    outs = []
    for p in prompts:
        eng = Engine(cfg, params, max_batch=1, max_seq=max_seq)
        req = Request(rid=0, prompt=p, max_new=max_new)
        assert eng.serve([req])["done"]
        outs.append(req.out)
    return outs


def test_mixed_length_batch_matches_sequential():
    """5 mixed-length requests on a 3-slot slab (forces slot reuse and a
    mixed-depth slab) == each request served alone."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    eng = Engine(cfg, params, max_batch=3, max_seq=48)
    reqs = [Request(rid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    stats = eng.serve(reqs)
    assert stats["done"]
    ref = _sequential_reference(cfg, params, prompts, max_new=6)
    for i, (req, expect) in enumerate(zip(reqs, ref)):
        assert req.out == expect, (i, len(prompts[i]), req.out, expect)


def test_two_requests_different_lengths_concurrent():
    """The acceptance-criteria shape: two concurrent requests of different
    prompt lengths, token-identical to one-at-a-time serving."""
    cfg, params = _setup()
    p_short, p_long = [5, 6, 7], [9, 8, 7, 6, 5, 4, 3, 2, 1, 10, 11]
    eng = Engine(cfg, params, max_batch=2, max_seq=48)
    reqs = [Request(rid=0, prompt=p_short, max_new=5),
            Request(rid=1, prompt=p_long, max_new=5)]
    assert eng.serve(reqs)["done"]
    ref = _sequential_reference(cfg, params, [p_short, p_long], max_new=5)
    assert reqs[0].out == ref[0]
    assert reqs[1].out == ref[1]


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-1.2b"])
def test_mixed_length_batch_recurrent_families(arch):
    """SSM/hybrid slabs (exact-length prefill buckets, position-free or
    mixed caches) also match the sequential reference."""
    cfg, params = _setup(arch)
    prompts = _prompts(cfg, lens=(4, 7, 4))
    eng = Engine(cfg, params, max_batch=2, max_seq=48)
    reqs = [Request(rid=i, prompt=p, max_new=4)
            for i, p in enumerate(prompts)]
    assert eng.serve(reqs)["done"]
    ref = _sequential_reference(cfg, params, prompts, max_new=4)
    for req, expect in zip(reqs, ref):
        assert req.out == expect


def test_sampling_determinism_fixed_key():
    """Same seed -> identical sampled streams; different seed -> (almost
    surely) different ones."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    sc = SamplingConfig(mode="top_k", top_k=8, temperature=0.7)

    def run(seed):
        eng = Engine(cfg, params, max_batch=3, max_seq=48,
                     sampling=sc, seed=seed)
        reqs = [Request(rid=i, prompt=p, max_new=8)
                for i, p in enumerate(prompts)]
        assert eng.serve(reqs)["done"]
        return [r.out for r in reqs]

    assert run(42) == run(42)
    assert run(42) != run(7)


def test_sample_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0], [3.0, 0.0, 0.0, 0.0]])
    key = jax.random.PRNGKey(0)
    greedy = sample(logits, key, SamplingConfig())
    np.testing.assert_array_equal(np.asarray(greedy), [1, 0])
    # top_k=1 == greedy regardless of key/temperature
    top1 = sample(logits, key, SamplingConfig(mode="top_k", top_k=1,
                                              temperature=3.0))
    np.testing.assert_array_equal(np.asarray(top1), [1, 0])
    # top_k restricts support
    for s in range(5):
        t = sample(logits, jax.random.PRNGKey(s),
                   SamplingConfig(mode="top_k", top_k=2, temperature=1.0))
        assert int(t[0]) in (1, 2) and int(t[1]) in (0, 1, 2, 3)
    with pytest.raises(ValueError):
        SamplingConfig(mode="nucleus")
    with pytest.raises(ValueError):
        SamplingConfig(mode="temperature", temperature=0.0)
    with pytest.raises(ValueError):
        SamplingConfig(mode="top_k", top_k=4, temperature=0.0)


def test_engine_metrics_and_bucketing():
    """Bucketed prefill: one jit call admits same-bucket prompts together;
    metrics account every token."""
    cfg, params = _setup()
    prompts = _prompts(cfg, lens=(3, 5, 4, 6))   # all in one 16-bucket
    eng = Engine(cfg, params, max_batch=4, max_seq=48, prefill_bucket=16)
    reqs = [Request(rid=i, prompt=p, max_new=3)
            for i, p in enumerate(prompts)]
    stats = eng.serve(reqs)
    assert stats["done"]
    assert stats["prefill_calls"] == 1           # one bucket, one jit call
    assert stats["prefill_tokens"] == sum(len(p) for p in prompts)
    # every emitted token is accounted: 1 from prefill + rest from decode
    assert stats["decode_tokens"] == sum(len(r.out) - 1 for r in reqs)
    assert 0.0 < stats["occupancy"] <= 1.0
    assert stats["decode_tok_s"] > 0


def test_engine_rejects_oversized_prompt():
    cfg, params = _setup()
    eng = Engine(cfg, params, max_batch=2, max_seq=16)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=list(range(1, 17)), max_new=2))
    with pytest.raises(ValueError):
        Engine(cfg, params, max_batch=2, max_seq=16, prefill_bucket=0)


def test_engine_reuse_reports_per_call_stats():
    """serve() stats cover that call only; Engine.metrics keeps the
    lifetime totals."""
    cfg, params = _setup()
    eng = Engine(cfg, params, max_batch=2, max_seq=48)
    p = _prompts(cfg, lens=(3, 5))
    s1 = eng.serve([Request(rid=0, prompt=p[0], max_new=4),
                    Request(rid=1, prompt=p[1], max_new=4)])
    s2 = eng.serve([Request(rid=2, prompt=p[0], max_new=4)])
    assert s1["done"] and s2["done"]
    assert s2["decode_tokens"] == 3          # 4 emitted - 1 from prefill
    assert s2["prefill_tokens"] == len(p[0])
    assert s2["ticks"] < s1["ticks"] + s2["ticks"]
    assert eng.metrics.decode_tokens == \
        s1["decode_tokens"] + s2["decode_tokens"]
