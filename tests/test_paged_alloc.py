"""Property tests for the paged-cache block allocator (host-side).

Invariants the engine's reservation logic leans on:
  * no double-allocation: outstanding blocks are unique, never the garbage
    block, and never handed out twice while held;
  * frees return to the pool: used + free == num_blocks - 1 always, and a
    full release cycle restores the initial free count;
  * backpressure ordering: an alloc that fails (pool short) changes
    nothing, and the exact same request succeeds once enough blocks are
    released;
  * refcount / copy-on-write (prefix sharing): a block written at
    admission is solely owned at write time, shared blocks always carry
    >= 2 owners, a block returns to the free pool ONLY at refcount 0, and
    pool accounting stays exact through random admit / evict / finish
    sequences.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install hypothesis)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.serve.paged import GARBAGE_BLOCK, BlockAllocator, blocks_needed
from repro.serve.prefix_cache import PrefixCache


@settings(max_examples=60, deadline=None)
@given(num_blocks=st.integers(2, 24),
       ops=st.lists(st.tuples(st.sampled_from(["alloc", "release"]),
                              st.integers(0, 8)), max_size=40))
def test_allocator_invariants(num_blocks, ops):
    a = BlockAllocator(num_blocks, block_size=4)
    capacity = num_blocks - 1                 # block 0 is reserved garbage
    assert a.free_blocks == capacity
    held: list[list[int]] = []
    for op, n in ops:
        if op == "alloc":
            before = a.free_blocks
            got = a.alloc(n)
            if n > before:
                assert got is None            # backpressure...
                assert a.free_blocks == before  # ...with no side effects
            else:
                assert got is not None and len(got) == n
                held.append(got)
        elif held:
            a.release(held.pop(n % len(held)))
        outstanding = [b for blocks in held for b in blocks]
        # no double-allocation, never the garbage block, all in range
        assert len(outstanding) == len(set(outstanding))
        assert all(GARBAGE_BLOCK < b < num_blocks for b in outstanding)
        # conservation: every block is either free or held
        assert a.free_blocks + len(outstanding) == capacity
        assert a.used_blocks == len(outstanding)
    for blocks in held:
        a.release(blocks)
    assert a.free_blocks == capacity and a.used_blocks == 0


@settings(max_examples=60, deadline=None)
@given(num_blocks=st.integers(3, 24), want=st.integers(1, 24))
def test_failed_alloc_succeeds_after_release(num_blocks, want):
    """FIFO head-of-line semantics: a request that backpressures succeeds
    unchanged once blocks free up."""
    a = BlockAllocator(num_blocks, block_size=4)
    hog = a.alloc(a.free_blocks)              # drain the pool
    assert a.alloc(min(want, num_blocks - 1)) is None or want == 0
    a.release(hog)
    if want <= num_blocks - 1:
        got = a.alloc(want)
        assert got is not None and len(got) == want
    else:
        assert a.alloc(want) is None          # can never fit: stays None


@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_refcount_cow_invariants(data):
    """Random admit / complete / cancel / finish / evict sequences through
    the prefix cache, mirroring the engine's staged-admission lifecycle
    (admit = reserve shared refs + private tail, complete = staged prefill
    lands and inserts its prefix, cancel = mid-chunked-prefill abort that
    releases the whole reservation, finish = a decoded request frees its
    slot):

      * copy-on-write — every block an admission WRITES (its private tail)
        is solely owned at write time; every shared block has >= 2 owners
        and is never in the written set;
      * cancellation exact — a cancelled staged admission returns shared
        blocks to their pre-admission refcounts and frees its private tail
        (nothing was inserted, so nothing leaks);
      * eviction only at refcount 0 — a block reaches the free pool
        exactly when its last owner releases it, never earlier;
      * accounting exact — free + refcounted == capacity after every op,
        and a full teardown (finish/cancel all + sweep the cache) restores
        the empty pool.
    """
    bs = 4
    num_blocks = data.draw(st.integers(4, 24), label="num_blocks")
    capacity = num_blocks - 1
    max_seq = capacity * bs
    a = BlockAllocator(num_blocks, bs)
    cache = PrefixCache(block_size=bs, backend=a, max_nodes=8)
    live: list[list[int]] = []                # decoded requests' tables
    staged: list[tuple] = []                  # (prompt, table) mid-prefill
    token = st.integers(0, 2)                 # tiny alphabet: forces sharing
    for _ in range(data.draw(st.integers(1, 25), label="n_ops")):
        op = data.draw(st.sampled_from(["admit", "admit", "complete",
                                        "cancel", "finish"]), label="op")
        if op == "admit":
            plen = data.draw(st.integers(1, max_seq - 1), label="plen")
            prompt = data.draw(st.lists(token, min_size=plen,
                                        max_size=plen), label="prompt")
            hit = cache.match(prompt, max_len=plen - 1)
            shared = list(hit.blocks) if hit else []
            need = blocks_needed(plen, 1, max_seq, bs) - len(shared)
            assert need >= 0
            if shared:                        # ref FIRST: pins the matched
                a.ref(shared)                 # node against eviction below
            if need > a.free_blocks:
                cache.evict_for(need)         # LRU over refcount-0 nodes
            fresh = a.alloc(need)
            if fresh is None:
                if shared:
                    a.release(shared)         # backpressure: no change
                continue
            # COW: the engine writes ONLY the private tail blocks
            assert all(a.writable(b) for b in fresh)
            assert all(a.refcount(b) >= 2 and not a.writable(b)
                       for b in shared)
            staged.append((prompt, shared + fresh))
        elif op == "complete" and staged:
            prompt, table = staged.pop(data.draw(
                st.integers(0, len(staged) - 1), label="done"))
            nb = len(prompt) // bs            # prefill landed: cache the
            if nb:                            # whole-block prefix
                cache.insert(prompt[:nb * bs], blocks=table[:nb])
            live.append(table)
        elif op == "cancel" and staged:
            # mid-chunked-prefill cancel: the whole reservation (shared
            # refs AND private tail) goes back in one release
            _, table = staged.pop(data.draw(
                st.integers(0, len(staged) - 1), label="victim"))
            a.release(table)
        elif op == "finish" and live:
            a.release(live.pop(data.draw(
                st.integers(0, len(live) - 1), label="victim")))
        # pool accounting exact after every op
        held = sum(1 for b in range(1, num_blocks) if a.refcount(b) > 0)
        assert a.free_blocks + held == capacity
        assert a.used_blocks == held
        # a block is free iff its refcount is 0 (eviction never jumps it)
        assert all(a.refcount(b) == 0 for b in a._free_set)
        # live/staged tables always survive eviction (their refs pin them)
        assert all(a.refcount(b) >= 1 for t in live for b in t)
        assert all(a.refcount(b) >= 1 for _, t in staged for b in t)
    for _, t in staged:
        a.release(t)                          # cancel the rest
    for t in live:
        a.release(t)
    cache.evict_for(num_blocks)               # sweeps every remaining node
    assert cache.node_count == 0
    assert a.free_blocks == capacity and a.used_blocks == 0


@settings(max_examples=60, deadline=None)
@given(prompt=st.integers(1, 512), max_new=st.integers(1, 256),
       max_seq=st.integers(2, 512), bs=st.integers(1, 64))
def test_blocks_needed_bounds(prompt, max_new, max_seq, bs):
    """Reservation covers the whole lifetime but never exceeds a full
    max_seq row's worth of blocks."""
    n = blocks_needed(prompt, max_new, max_seq, bs)
    lifetime = min(prompt + max_new, max_seq)
    assert n * bs >= lifetime                 # enough for prompt + decode
    assert (n - 1) * bs < lifetime            # tight: no over-reservation
    assert n <= -(-max_seq // bs)             # capped at the row budget
