"""Property tests for the paged-cache block allocator (host-side).

Invariants the engine's reservation logic leans on:
  * no double-allocation: outstanding blocks are unique, never the garbage
    block, and never handed out twice while held;
  * frees return to the pool: used + free == num_blocks - 1 always, and a
    full release cycle restores the initial free count;
  * backpressure ordering: an alloc that fails (pool short) changes
    nothing, and the exact same request succeeds once enough blocks are
    released.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install hypothesis)")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.serve.paged import GARBAGE_BLOCK, BlockAllocator, blocks_needed


@settings(max_examples=60, deadline=None)
@given(num_blocks=st.integers(2, 24),
       ops=st.lists(st.tuples(st.sampled_from(["alloc", "release"]),
                              st.integers(0, 8)), max_size=40))
def test_allocator_invariants(num_blocks, ops):
    a = BlockAllocator(num_blocks, block_size=4)
    capacity = num_blocks - 1                 # block 0 is reserved garbage
    assert a.free_blocks == capacity
    held: list[list[int]] = []
    for op, n in ops:
        if op == "alloc":
            before = a.free_blocks
            got = a.alloc(n)
            if n > before:
                assert got is None            # backpressure...
                assert a.free_blocks == before  # ...with no side effects
            else:
                assert got is not None and len(got) == n
                held.append(got)
        elif held:
            a.release(held.pop(n % len(held)))
        outstanding = [b for blocks in held for b in blocks]
        # no double-allocation, never the garbage block, all in range
        assert len(outstanding) == len(set(outstanding))
        assert all(GARBAGE_BLOCK < b < num_blocks for b in outstanding)
        # conservation: every block is either free or held
        assert a.free_blocks + len(outstanding) == capacity
        assert a.used_blocks == len(outstanding)
    for blocks in held:
        a.release(blocks)
    assert a.free_blocks == capacity and a.used_blocks == 0


@settings(max_examples=60, deadline=None)
@given(num_blocks=st.integers(3, 24), want=st.integers(1, 24))
def test_failed_alloc_succeeds_after_release(num_blocks, want):
    """FIFO head-of-line semantics: a request that backpressures succeeds
    unchanged once blocks free up."""
    a = BlockAllocator(num_blocks, block_size=4)
    hog = a.alloc(a.free_blocks)              # drain the pool
    assert a.alloc(min(want, num_blocks - 1)) is None or want == 0
    a.release(hog)
    if want <= num_blocks - 1:
        got = a.alloc(want)
        assert got is not None and len(got) == want
    else:
        assert a.alloc(want) is None          # can never fit: stays None


@settings(max_examples=60, deadline=None)
@given(prompt=st.integers(1, 512), max_new=st.integers(1, 256),
       max_seq=st.integers(2, 512), bs=st.integers(1, 64))
def test_blocks_needed_bounds(prompt, max_new, max_seq, bs):
    """Reservation covers the whole lifetime but never exceeds a full
    max_seq row's worth of blocks."""
    n = blocks_needed(prompt, max_new, max_seq, bs)
    lifetime = min(prompt + max_new, max_seq)
    assert n * bs >= lifetime                 # enough for prompt + decode
    assert (n - 1) * bs < lifetime            # tight: no over-reservation
    assert n <= -(-max_seq // bs)             # capped at the row budget
