"""Probe-extrapolation solver: exact on synthetic component costs."""
import pytest

from repro.launch.accounting import extrapolate, probe_plan
from repro.models.registry import get_config


def _fake_rec(flops, bytes_, coll):
    return {
        "hlo_flops": flops, "hlo_bytes": bytes_, "collective_bytes": coll,
        "collective_breakdown": {
            "all-gather": coll * 0.5, "all-reduce": coll * 0.5,
            "reduce-scatter": 0, "all-to-all": 0, "collective-permute": 0},
    }


def test_extrapolate_dense_exact():
    cfg = get_config("yi-9b")
    probes, full = probe_plan(cfg, "train")
    base, layer = 7.0, 3.0
    recs = [_fake_rec(base + layer * c["layer"], 2 * (base + layer * c["layer"]),
                      10 * c["layer"]) for _, c in probes]
    out = extrapolate(recs, probes, full)
    L = cfg.num_layers
    assert out["hlo_flops"] == pytest.approx(base + layer * L)
    assert out["collective_bytes"] == pytest.approx(10 * L)
    assert out["probe_residual"] < 1e-9


def test_extrapolate_hybrid_three_components():
    cfg = get_config("zamba2-1.2b")
    probes, full = probe_plan(cfg, "train")
    base, attn, mamba = 5.0, 11.0, 2.0

    def f(c):
        return base * c["base"] + attn * c["attn"] + mamba * c["mamba"]

    recs = [_fake_rec(f(c), f(c), f(c)) for _, c in probes]
    out = extrapolate(recs, probes, full)
    expect = base + attn * full["attn"] + mamba * full["mamba"]
    assert full["attn"] == 7 and full["mamba"] == 38
    assert out["hlo_flops"] == pytest.approx(expect)


def test_extrapolate_encdec_components():
    cfg = get_config("whisper-base")
    probes, full = probe_plan(cfg, "train")
    base, enc, dec = 1.0, 4.0, 9.0

    def f(c):
        return base + enc * c.get("enc", 0) + dec * c.get("dec", 0)

    recs = [_fake_rec(f(c), f(c), 0) for _, c in probes]
    out = extrapolate(recs, probes, full)
    assert out["hlo_flops"] == pytest.approx(base + 6 * enc + 6 * dec)


def test_probe_plan_moe_counts():
    cfg = get_config("deepseek-v2-236b")
    probes, full = probe_plan(cfg, "train")
    # first_dense=1 lives in 'base'; full stack has 59 MoE layers
    assert full == {"base": 1, "moe": 59}
    assert probes[0][1] == {"base": 1, "moe": 1}


def test_collective_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
      %ag = bf16[16,512,128]{2,1,0} all-gather(%x), replica_groups={}
      %ar.1 = f32[1024]{0} all-reduce(%y), to_apply=%sum
      %cp = u8[64,64]{1,0} collective-permute(%z)
      %dot = f32[8,8]{1,0} dot(%a, %b)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 512 * 128 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["collective-permute"] == 64 * 64
    assert out["total"] == (16 * 512 * 128 * 2 + 4096 + 4096)
