"""Quantizers + real-valued LUNA matmul (zero-point algebra, STE)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -e .[dev])")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import layers, quant
from repro.core.luna import LunaMode


def test_quant_roundtrip_exact_on_grid():
    """Values on the quantization grid survive a round trip exactly."""
    qp = quant.QParams(jnp.float32(0.5), jnp.float32(3.0), 4)
    x = (jnp.arange(16, dtype=jnp.float32) - 3.0) * 0.5
    np.testing.assert_allclose(quant.dequantize(quant.quantize(x, qp), qp), x)


@given(seed=st.integers(0, 2**31 - 1), bits=st.sampled_from([4, 8]))
@settings(max_examples=25, deadline=None)
def test_quant_error_bound(seed, bits):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    qp = quant.calibrate(x, bits)
    err = np.asarray(quant.quant_error(x, qp))
    assert np.abs(err).max() <= float(qp.scale) * 0.5001 + 1e-6


def test_luna_matmul_f32_exact_mode_close_to_matmul():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    got = quant.luna_matmul_f32(x, w, LunaMode.OPT_DC, bits=8)
    ref = x @ w
    # int8 quantization error only
    rel = np.abs(np.asarray(got - ref)).max() / np.abs(np.asarray(ref)).max()
    assert rel < 0.05, rel


def test_approx_modes_have_larger_but_bounded_error():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    ref = np.asarray(x @ w)
    errs = {}
    for m in (LunaMode.OPT_DC, LunaMode.APPROX_DC, LunaMode.APPROX_DC2):
        got = np.asarray(quant.luna_matmul_f32(x, w, m, bits=4))
        errs[m] = np.abs(got - ref).mean()
    assert errs[LunaMode.OPT_DC] <= errs[LunaMode.APPROX_DC2] * 1.5
    assert errs[LunaMode.APPROX_DC2] <= errs[LunaMode.APPROX_DC] * 1.5
    # paper Fig 13 ordering: exact < approx2 < approx (approx2's balanced err)


def test_ste_gradients_flow():
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8, 3), jnp.float32) * 0.1

    def loss(w):
        return jnp.sum(quant.ste_luna_matmul(x, w, "approx_dc", 4) ** 2)

    g = jax.grad(loss)(w)
    assert g.shape == w.shape
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


@pytest.mark.parametrize("mode", layers.QUANT_MODES)
def test_quant_matmul_all_modes(mode):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    cfg = layers.QuantConfig(mode=mode)
    y = layers.quant_matmul(x, w, cfg, group="mlp")
    assert y.shape == (4, 8)
    assert np.isfinite(np.asarray(y)).all()
    if mode == "bf16":
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-5)


def test_quant_matmul_respects_targets():
    x = jnp.ones((2, 4), jnp.float32)
    w = jnp.ones((4, 4), jnp.float32)
    cfg = layers.QuantConfig(mode="luna_approx", targets=("mlp",))
    exact = layers.quant_matmul(x, w, cfg, group="attn")  # not targeted
    np.testing.assert_allclose(np.asarray(exact), np.asarray(x @ w))


def test_nf4_mux_tree_matches_gather():
    """The programmable-LUT invariant: the paper's 15-select mux tree computes
    exactly the same dequant as a direct codebook gather."""
    from repro.core import lut
    rng = np.random.default_rng(4)
    codes = jnp.asarray(rng.integers(0, 16, (37, 13)).astype(np.int32))
    cb = jnp.asarray(lut.NF4_CODEBOOK)
    via_tree = lut.codebook_dequant(codes, cb)
    via_gather = cb[codes]
    np.testing.assert_array_equal(np.asarray(via_tree), np.asarray(via_gather))


def test_nf4_quant_error_comparable_to_uniform():
    """NF4 through the LUT is a usable weight codec (same ballpark as uniform
    int4; which wins depends on distribution/blocking)."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32))
    ref = np.asarray(x @ w)
    e_nf4 = np.abs(np.asarray(layers.quant_matmul(
        x, w, layers.QuantConfig(mode="lut_nf4"))) - ref).mean()
    e_u4 = np.abs(np.asarray(layers.quant_matmul(
        x, w, layers.QuantConfig(mode="int4_dequant"))) - ref).mean()
    assert e_nf4 < 1.25 * e_u4
