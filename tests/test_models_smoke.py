"""Per-architecture smoke tests: REDUCED configs, one forward/train step on
CPU, asserting output shapes + finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import ARCH_IDS, get_config, get_model

BATCH, SEQ = 2, 64


def _batch_for(cfg):
    rng = np.random.default_rng(0)
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ))),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ)))}
    if cfg.family == "encdec":
        b["frames"] = jnp.asarray(
            rng.normal(size=(BATCH, cfg.encdec.enc_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        p = cfg.vlm.num_patches
        b["patches"] = jnp.asarray(
            rng.normal(size=(BATCH, p, cfg.d_model)), jnp.dtype(cfg.dtype))
        b["tokens"] = b["tokens"][:, : SEQ - p]
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss)), (arch, float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    s_max = SEQ + 8
    caches = model.init_cache(BATCH, s_max)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, SEQ)))

    kwargs = {}
    if cfg.family == "encdec":
        kwargs["frames"] = jnp.asarray(
            rng.normal(size=(BATCH, cfg.encdec.enc_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        p = cfg.vlm.num_patches
        kwargs["patches"] = jnp.asarray(
            rng.normal(size=(BATCH, p, cfg.d_model)), jnp.dtype(cfg.dtype))
        prompt = prompt[:, : SEQ - p]

    logits, state = jax.jit(model.prefill)(params, prompt, caches, **kwargs)
    assert logits.shape == (BATCH, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    step = jax.jit(model.decode_step)
    logits2, state = step(params, tok, state, jnp.int32(SEQ))
    assert logits2.shape == (BATCH, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


def test_decode_matches_prefill_dense():
    """Teacher-forced decode == prefill logits (cache correctness), dense."""
    cfg = get_config("yi-9b").reduced(dtype="float32", attn_impl="full")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 8)))

    hidden, _, _ = model.forward(params, toks)
    full_logits = model.logits(params, hidden)

    caches = model.init_cache(1, 16)
    step = jax.jit(model.decode_step)
    logits_seq = []
    state = caches
    for i in range(8):
        lg, state = step(params, toks[:, i:i + 1], state, jnp.int32(i))
        logits_seq.append(np.asarray(lg[0, 0], np.float32))
    inc = np.stack(logits_seq)
    ref = np.asarray(full_logits[0], np.float32)
    np.testing.assert_allclose(inc, ref, rtol=2e-3, atol=2e-3)


def test_decode_matches_prefill_ssm():
    """Step decode recurrence == chunked SSD outputs (mamba2)."""
    cfg = get_config("mamba2-1.3b").reduced(dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 32)))

    hidden, _ = model.forward(params, toks)
    from repro.core.layers import quant_matmul
    full_logits = quant_matmul(hidden, params["lm_head"], None)

    state = model.init_cache(1, 32)
    step = jax.jit(model.decode_step)
    outs = []
    for i in range(32):
        lg, state = step(params, toks[:, i:i + 1], state, jnp.int32(i))
        outs.append(np.asarray(lg[0, 0], np.float32))
    inc = np.stack(outs)
    ref = np.asarray(full_logits[0], np.float32)
    np.testing.assert_allclose(inc, ref, rtol=5e-3, atol=5e-3)


def test_luna_quant_mode_through_model():
    """The paper's technique as a first-class feature: same arch, quantized."""
    cfg = get_config("yi-9b").reduced()
    from repro.core.layers import QuantConfig
    cfg_q = get_config("yi-9b").reduced(
        quant=QuantConfig(mode="luna_approx", bits=4))
    model, model_q = get_model(cfg), get_model(cfg_q)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    l0, _ = jax.jit(model.loss)(params, batch)
    l1, _ = jax.jit(model_q.loss)(params, batch)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert abs(float(l0) - float(l1)) > 1e-6  # quantization changed the math
