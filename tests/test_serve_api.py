"""Serving API v2: EngineConfig, request lifecycle, scheduler bounds.

The load-bearing pins:
  * legacy ``Engine(cfg, params, **knobs)`` is GONE — the one-release
    deprecation window closed, so knob kwargs now raise ``TypeError``
    and every construction goes through ``EngineConfig``;
  * incremental tokens from a ``RequestHandle`` (generator AND on-token
    callback) equal the final ``req.out`` exactly;
  * ``cancel()`` releases blocks and staged state mid-chunked-prefill and
    restores shared-block refcounts after a warm prefix admission, with
    exact pool accounting;
  * the scheduler orders by priority class with deadline tie-breaks, ages
    at most one bucket (priority inversion bound), never starves, and owns
    the head-of-line stall state ``submit()``/``serve()`` share;
  * ``repro.serve.engine`` is substrate-blind: every substrate decision
    lives behind ``CacheBackend``.
"""
import argparse
import inspect
import random
import warnings

import jax
import numpy as np
import pytest

from repro.models.registry import get_config, get_model
from repro.serve.config import EngineConfig
from repro.serve.engine import Engine, Request, Scheduler
from repro.serve.sampling import SamplingConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                           # property tier is optional
    HAVE_HYPOTHESIS = False


def _setup(arch="yi-9b", **over):
    cfg = get_config(arch).reduced(dtype="float32", attn_impl="full", **over)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, params


def _drain(eng, max_ticks=256):
    ticks = 0
    while not eng.idle and ticks < max_ticks:
        eng.step()
        ticks += 1
    assert ticks < max_ticks, "engine failed to drain"


# ---------------------------------------------------------------------------
# EngineConfig + deprecation shim
# ---------------------------------------------------------------------------

def test_engine_config_validation():
    EngineConfig()                            # defaults are valid
    with pytest.raises(ValueError, match="prefill_bucket"):
        EngineConfig(prefill_bucket=0)
    with pytest.raises(ValueError, match="prefill_chunk"):
        EngineConfig(prefill_chunk=0)
    with pytest.raises(ValueError, match="max_batch"):
        EngineConfig(max_batch=0)
    with pytest.raises(ValueError, match="starvation_bound"):
        EngineConfig(starvation_bound=0)
    # family cross-rules, single-sourced
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(paged=True).validate("ssm")
    with pytest.raises(ValueError, match="modality"):
        EngineConfig().validate("vlm")
    with pytest.raises(ValueError, match="modality"):
        EngineConfig().validate("encdec")
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(prefix_cache=True).validate("dense")
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(prefix_cache=True).validate("hybrid")
    EngineConfig(prefix_cache=True).validate("ssm")
    EngineConfig(paged=True, prefix_cache=True).validate("hybrid")


def test_engine_config_from_args():
    ap = argparse.ArgumentParser()
    EngineConfig.add_cli_args(ap)
    args = ap.parse_args(["--max-batch", "3", "--paged", "--block-size",
                          "8", "--prefill-chunk", "4", "--sampling",
                          "top_k", "--top-k", "5", "--temperature", "0.7",
                          "--seed", "9"])
    c = EngineConfig.from_args(args, max_seq=64)
    assert c.max_batch == 3 and c.max_seq == 64
    assert c.paged and c.block_size == 8 and c.prefill_chunk == 4
    assert c.sampling == SamplingConfig(mode="top_k", top_k=5,
                                        temperature=0.7)
    assert c.seed == 9
    # flags left unset fall back to the dataclass defaults
    args2 = ap.parse_args([])
    c2 = EngineConfig.from_args(args2)
    assert c2.max_batch == 8 and not c2.paged and c2.prefill_chunk is None


def test_legacy_kwargs_shim_removed():
    """Satellite pin: the pre-v2 ``Engine(cfg, params, **knobs)`` shim is
    gone — knob kwargs raise ``TypeError`` (no silent acceptance, no
    DeprecationWarning path left), the shim helper no longer exists, and
    the ``EngineConfig`` construction still works and serves."""
    cfg, params = _setup()
    with pytest.raises(TypeError):
        Engine(cfg, params, max_batch=2)
    with pytest.raises(TypeError):
        Engine(cfg, params, EngineConfig(), max_batch=2)
    with pytest.raises(TypeError):
        Engine(cfg, params, bogus_knob=1)
    import repro.serve.config as config_mod
    assert not hasattr(config_mod, "config_from_legacy_kwargs")
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48,
                                               paged=True, block_size=8))
    assert not [w for w in rec if issubclass(w.category, DeprecationWarning)]
    req = Request(rid=0, prompt=[3, 4, 5], max_new=4)
    assert eng.serve([req])["done"] and len(req.out) == 4


def test_engine_module_is_substrate_blind():
    """Acceptance pin: every substrate decision lives behind CacheBackend —
    the engine module neither branches on family capability sets nor
    probes cache leaves nor touches the block allocator."""
    import repro.serve.engine as engine_mod
    src = inspect.getsource(engine_mod)
    for forbidden in ("PAGED_FAMILIES", "PADDED_PREFILL_FAMILIES",
                      "_find_paged_leaves", "_find_batch_axes",
                      "BlockAllocator", "GARBAGE_BLOCK", "blocks_needed"):
        assert forbidden not in src, forbidden


# ---------------------------------------------------------------------------
# streaming
# ---------------------------------------------------------------------------

def test_streaming_tokens_match_final_output():
    """Acceptance pin: the incremental stream (generator AND on-token
    callback) equals the final ``req.out`` exactly, and matches a fresh
    engine serving the same request."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    seen = []
    req = Request(rid=7, prompt=[5, 6, 7, 8], max_new=6)
    handle = eng.submit(req, on_token=seen.append)
    assert handle                             # admitted immediately
    streamed = list(handle.tokens())
    assert handle.done and not handle.cancelled
    assert streamed == handle.out == req.out
    assert seen == streamed
    assert len(streamed) == 6

    ref_eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    ref = Request(rid=7, prompt=[5, 6, 7, 8], max_new=6)
    assert ref_eng.serve([ref])["done"]
    assert streamed == ref.out


def test_streaming_unadmitted_handle_waits_for_capacity():
    """A falsy handle's generator re-attempts admission between ticks and
    still streams the exact final output."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    first = Request(rid=0, prompt=[1, 2, 3], max_new=4)
    assert eng.submit(first)
    second = Request(rid=1, prompt=[4, 5], max_new=3)
    handle = eng.submit(second)
    assert not handle                         # no slot free yet
    streamed = list(handle.tokens())
    assert first.done and second.done
    assert streamed == second.out and len(streamed) == 3

    ref_eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    ref = Request(rid=1, prompt=[4, 5], max_new=3)
    assert ref_eng.serve([ref])["done"]
    assert streamed == ref.out


def test_streaming_interleaves_with_chunked_admission():
    """Streaming one handle while a chunked admission is mid-flight: both
    finish and the stream stays exact."""
    cfg, params = _setup()
    rng = np.random.default_rng(2)
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48,
                                           prefill_chunk=8))
    short = Request(rid=0, prompt=[3, 1, 4], max_new=8)
    h_short = eng.submit(short)
    long = Request(rid=1,
                   prompt=rng.integers(1, cfg.vocab_size, 20).tolist(),
                   max_new=3)
    assert eng.submit(long)                   # staged admission starts
    streamed = list(h_short.tokens())
    assert streamed == short.out and short.done
    _drain(eng)
    assert long.done and len(long.out) == 3


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_mid_chunked_prefill_releases_blocks_exactly():
    """Satellite pin: cancelling a staged (chunked) admission releases its
    reserved blocks and staged state; pool accounting is exact and the
    engine keeps serving token-identically."""
    cfg, params = _setup()
    rng = np.random.default_rng(4)
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=64,
                                           paged=True, block_size=8,
                                           prefill_chunk=8))
    short = Request(rid=0, prompt=[1, 2, 3], max_new=12)
    assert eng.submit(short)
    used0 = eng.allocator.used_blocks
    free0 = eng.allocator.free_blocks
    long = Request(rid=1,
                   prompt=rng.integers(1, cfg.vocab_size, 30).tolist(),
                   max_new=4)
    handle = eng.submit(long)
    assert handle and long.out == []          # staged, nothing emitted
    eng.step()                                # one chunk lands
    assert eng._chunked and long.out == []
    assert eng.allocator.used_blocks > used0  # tail blocks reserved
    assert handle.cancel()
    assert long.cancelled and long.done and long.out == []
    assert not eng._chunked
    assert eng.allocator.used_blocks == used0
    assert eng.allocator.free_blocks == free0
    assert not handle.cancel()                # idempotent: nothing left
    assert eng.metrics.cancelled == 1

    _drain(eng)                               # short request unharmed
    ref_eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=64))
    ref = Request(rid=0, prompt=[1, 2, 3], max_new=12)
    assert ref_eng.serve([ref])["done"]
    assert short.out == ref.out
    assert eng.allocator.used_blocks == 0


def test_cancel_warm_prefix_admission_restores_refcounts():
    """Satellite pin: cancelling a warm (prefix-cache) admission returns
    every shared block to its pre-admission refcount and frees the private
    tail; the cached prefix still serves later admissions."""
    cfg, params = _setup()
    rng = np.random.default_rng(5)
    head = rng.integers(1, cfg.vocab_size, 16).tolist()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=64,
                                           paged=True, block_size=8,
                                           prefix_cache=True,
                                           prefill_chunk=8))
    cold = Request(rid=0, prompt=head + [7, 8], max_new=2)
    assert eng.serve([cold])["done"]          # populates the radix tree

    warm_prompt = head + rng.integers(1, cfg.vocab_size, 6).tolist()
    hit = eng.prefix_cache.match(warm_prompt, max_len=len(warm_prompt) - 1)
    assert hit is not None and len(hit.blocks) == 2
    refs0 = [eng.allocator.refcount(b) for b in hit.blocks]
    free0 = eng.allocator.free_blocks

    warm = Request(rid=1, prompt=warm_prompt, max_new=3)
    handle = eng.submit(warm)
    assert handle and eng._chunked            # staged warm admission
    assert [eng.allocator.refcount(b) for b in hit.blocks] == \
        [r + 1 for r in refs0]                # COW share took a ref
    assert eng.allocator.free_blocks < free0  # private tail allocated
    assert handle.cancel()
    assert [eng.allocator.refcount(b) for b in hit.blocks] == refs0
    assert eng.allocator.free_blocks == free0
    assert warm.cancelled and warm.out == []

    # the cached head still serves: same warm prompt, token-identical
    redo = Request(rid=2, prompt=warm_prompt, max_new=3)
    assert eng.serve([redo])["done"]
    ref_eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=64,
                                               paged=True, block_size=8))
    ref = Request(rid=2, prompt=warm_prompt, max_new=3)
    assert ref_eng.serve([ref])["done"]
    assert redo.out == ref.out


def test_cancel_active_and_queued_requests():
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    active = Request(rid=0, prompt=[1, 2], max_new=30)
    h_active = eng.submit(active)
    assert h_active
    eng.step()
    emitted = len(active.out)
    assert h_active.cancel()
    assert active.cancelled and len(active.out) == emitted
    assert eng.slots == [None] and not eng.active

    # queued via serve(): cancel before admission emits nothing
    queued = Request(rid=1, prompt=[3, 4], max_new=2)
    eng.scheduler.push(queued)
    assert eng.cancel(queued)
    assert queued.cancelled and queued.out == []
    assert eng.scheduler.pending == 0
    # a falsy (never queued) handle can still be closed out
    blocked_eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    assert blocked_eng.submit(Request(rid=2, prompt=[1], max_new=9))
    h = blocked_eng.submit(Request(rid=3, prompt=[2], max_new=1))
    assert not h and h.cancel() and h.cancelled


def test_cancel_from_on_token_callback_is_reentrancy_safe():
    """Review pin: cancelling from inside an on_token callback (the
    stop-sequence streaming pattern) must not crash the decode loop nor
    resurrect the request."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    req = Request(rid=0, prompt=[5, 6, 7], max_new=10)
    handle = eng.submit(req)

    def stop_after(n):
        def cb(tok):
            if len(req.out) >= n:
                handle.cancel()
        return cb

    eng._callbacks.setdefault(req, []).append(stop_after(3))
    assert handle
    for _ in range(12):
        if req.done:
            break
        eng.step()                            # must not KeyError
    assert req.cancelled and len(req.out) == 3
    assert eng.slots == [None, None] and not eng.active

    # cancel on the PREFILL emit (mid-admission): the request must not be
    # resurrected into a slot after cancel() returned True
    req2 = Request(rid=1, prompt=[1, 2], max_new=5)
    eng.submit(req2, on_token=lambda tok: eng.cancel(req2))
    assert req2.cancelled and len(req2.out) == 1
    assert eng.slots == [None, None] and not eng.active
    if eng.allocator is not None:
        assert eng.allocator.used_blocks == 0
    # the engine still serves normally afterwards
    ok = Request(rid=2, prompt=[3, 4], max_new=3)
    assert eng.serve([ok])["done"] and len(ok.out) == 3


def test_invalid_request_does_not_poison_the_scheduler():
    """Review pin: serve() validates BEFORE queueing — an oversized
    request raises and the engine stays fully serviceable."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=16))
    bad = Request(rid=0, prompt=list(range(1, 40)), max_new=2)
    with pytest.raises(ValueError):
        eng.serve([bad])
    assert eng.scheduler.pending == 0
    good = Request(rid=1, prompt=[1, 2, 3], max_new=2)
    assert eng.serve([good])["done"] and len(good.out) == 2
    # a poison entry pushed straight onto the scheduler is evicted on the
    # first admission attempt instead of wedging the queue forever
    eng.scheduler.push(bad)
    with pytest.raises(ValueError):
        eng.step()
    assert eng.scheduler.pending == 0
    good2 = Request(rid=2, prompt=[4, 5], max_new=2)
    assert eng.serve([good2])["done"]


def test_on_token_callback_registration_is_idempotent():
    """Review pin: a backpressured submit retried with the same callback
    fires once per token, and a cancelled falsy handle leaves no stale
    callback behind for a later request reusing the rid."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    assert eng.submit(Request(rid=0, prompt=[1], max_new=6))
    seen = []
    retry = Request(rid=1, prompt=[2, 3], max_new=3)
    assert not eng.submit(retry, on_token=seen.append)
    h = eng.submit(retry, on_token=seen.append)   # the documented retry
    streamed = list(h.tokens()) if h else list(
        eng.submit(retry, on_token=seen.append).tokens())
    assert retry.done
    assert seen == retry.out == streamed          # no double-fire

    # stale-callback leak: cancel a never-admitted handle, then reuse rid
    eng2 = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    assert eng2.submit(Request(rid=0, prompt=[1], max_new=4))
    ghost_tokens = []
    ghost = eng2.submit(Request(rid=7, prompt=[2], max_new=2),
                        on_token=ghost_tokens.append)
    assert not ghost                          # falsy: already unregistered
    assert not eng2._callbacks
    assert ghost.cancel()
    _drain(eng2)
    reuse = Request(rid=7, prompt=[3, 4], max_new=2)
    assert eng2.serve([reuse])["done"]
    assert ghost_tokens == []                     # ghost never fired


# ---------------------------------------------------------------------------
# scheduler: priority / deadline / aging / stall bookkeeping
# ---------------------------------------------------------------------------

def _req(rid, pri=0, dl=None):
    return Request(rid=rid, prompt=[1], max_new=1, priority=pri, deadline=dl)


def test_scheduler_priority_and_deadline_order():
    s = Scheduler(starvation_bound=8)
    s.push(_req(0, pri=0, dl=5.0))
    s.push(_req(1, pri=0, dl=1.0))
    s.push(_req(2, pri=1))
    order = []
    while s.pending:
        e = s.select()
        s.commit(e)
        order.append(e.req.rid)
    assert order == [2, 1, 0]                 # class first, then deadline
    # equal class and deadline: arrival order
    s.push(_req(3))
    s.push(_req(4))
    assert s.select().req.rid == 3


def test_scheduler_aging_promotes_one_bucket():
    s = Scheduler(starvation_bound=2)
    s.push(_req(0, pri=0))
    for rid in (1, 2):                        # two high admissions pass it
        s.push(_req(rid, pri=1))
        e = s.select()
        assert e.req.rid == rid
        s.commit(e)
    s.push(_req(3, pri=1))                    # newer high arrival
    e = s.select()                            # aged low outranks it now
    assert e.req.rid == 0
    assert s.effective_priority(e) == 1       # exactly one bucket, capped


def _sched_sim(ops, bound):
    """Drive a Scheduler through (push pri dl | pop) ops, asserting the two
    documented bounds at every step.  Returns the pop order."""
    s = Scheduler(starvation_bound=bound)
    pushes = 0
    earlier = {}                              # rid -> pushes before it
    pri_of = {}
    popped = []
    for op in ops:
        if op[0] == "push":
            rid = pushes
            earlier[rid] = len(s._queue)
            pri_of[rid] = op[1]
            s.push(_req(rid, pri=op[1], dl=op[2]))
            pushes += 1
        else:
            e = s.select()
            if e is None:
                continue
            # priority inversion never exceeds one bucket: nothing still
            # queued outranks the admitted request by 2+ classes
            for other in s._queue:
                if other is not e:
                    assert other.req.priority - e.req.priority <= 1, \
                        (other.req.priority, e.req.priority)
            s.commit(e)
            popped.append(e)
    # starvation bound: passed over at most starvation_bound times by
    # higher-priority work, plus once per earlier-arrived request and once
    # per strictly-higher-priority arrival (the documented bound)
    for e in popped:
        rid = e.req.rid
        higher = sum(1 for r, p in pri_of.items()
                     if r != rid and p > pri_of[rid])
        assert e.passed <= bound + earlier[rid] + higher, \
            (rid, e.passed, bound, earlier[rid], higher)
    return [e.req.rid for e in popped]


def test_scheduler_bounds_seeded_random():
    """Always-run spelling of the property test: seeded random op
    sequences over two adjacent priority classes."""
    for seed in range(25):
        rng = random.Random(seed)
        bound = rng.choice([1, 2, 4, 8])
        ops = []
        for _ in range(rng.randint(1, 60)):
            if rng.random() < 0.6:
                ops.append(("push", rng.choice([0, 1]),
                            rng.choice([None, rng.random()])))
            else:
                ops.append(("pop",))
        ops.extend([("pop",)] * 60)           # drain: no one starves
        _sched_sim(ops, bound)


if HAVE_HYPOTHESIS:
    @settings(max_examples=80, deadline=None)
    @given(data=st.data())
    def test_scheduler_bounds_property(data):
        """Satellite pin: under random priority/deadline/arrival
        sequences, no admitted request was ever passed over beyond the
        documented bound, and priority inversions never exceed one bucket
        (checked at every pop, with priorities spanning four classes)."""
        bound = data.draw(st.integers(1, 8), label="bound")
        two_class = data.draw(st.booleans(), label="two_class")
        pris = (0, 1) if two_class else (0, 1, 2, 3)
        ops = data.draw(st.lists(st.one_of(
            st.tuples(st.just("push"), st.sampled_from(pris),
                      st.none() | st.floats(0, 100, allow_nan=False)),
            st.tuples(st.just("pop"))), max_size=80), label="ops")
        ops = list(ops) + [("pop",)] * 80     # always drain
        if two_class:
            _sched_sim(ops, bound)
        else:
            # >2 classes: the starvation bound is only documented for
            # adjacent classes; still assert inversion bound + full drain
            s = Scheduler(starvation_bound=bound)
            pushes = 0
            for op in ops:
                if op[0] == "push":
                    s.push(_req(pushes, pri=op[1], dl=op[2]))
                    pushes += 1
                else:
                    e = s.select()
                    if e is None:
                        continue
                    for other in s._queue:
                        if other is not e:
                            assert other.req.priority - e.req.priority <= 1
                    s.commit(e)
            assert s.pending == 0
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                      "(pip install hypothesis)")
    def test_scheduler_bounds_property():
        pass


def test_stall_state_lives_in_scheduler_and_skips_rematch():
    """Satellite pin: a backpressured submit records its stall in the
    SCHEDULER (persistent across calls) and a retry with unchanged
    capacity skips the radix-tree re-walk entirely."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=32,
                                           paged=True, block_size=8,
                                           num_blocks=4,
                                           prefix_cache=True))
    hog = Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new=18)
    assert eng.submit(hog)                    # 3 blocks: pool now empty
    calls = []
    real_match = eng.prefix_cache.match

    def counting_match(*a, **kw):
        calls.append(1)
        return real_match(*a, **kw)

    eng.prefix_cache.match = counting_match
    def is_stalled(req):
        return eng.scheduler.stalled(
            req.rid, eng.backend.free_capacity,
            eng.backend.reservation_need(len(req.prompt), req.max_new))

    blocked = Request(rid=1, prompt=[6, 7, 8], max_new=8)
    blocked2 = Request(rid=2, prompt=[6, 7, 9], max_new=8)
    assert not eng.submit(blocked)            # pool short -> stall noted
    assert len(calls) == 1
    assert is_stalled(blocked)
    assert not eng.submit(blocked2)           # a SECOND blocked poller...
    assert len(calls) == 2
    assert is_stalled(blocked) and is_stalled(blocked2)
    assert not eng.submit(blocked)            # capacity unchanged for
    assert not eng.submit(blocked2)           # BOTH: per-rid stalls
    assert len(calls) == 2                    # ...no re-walk, no churn
    # a SMALLER request reusing a stalled rid is not gated by the record
    small = Request(rid=1, prompt=[9], max_new=1)
    assert not eng.scheduler.stalled(
        1, eng.backend.free_capacity,
        eng.backend.reservation_need(len(small.prompt), small.max_new))
    _drain(eng)                               # hog finishes, blocks free
    assert eng.submit(blocked)                # same request now admits
    assert len(calls) == 3
    assert not is_stalled(blocked)
    _drain(eng)
    assert blocked.done and len(blocked.out) == 8


def test_rid_collision_and_done_resubmission_are_explicit():
    """Review pins: a DIFFERENT request colliding with a live rid raises
    (instead of returning a truthy handle whose generator spins forever);
    resubmitting a finished request returns falsy and leaks no callback."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    a = Request(rid=0, prompt=[1, 2], max_new=8)
    assert eng.submit(a)
    with pytest.raises(ValueError, match="in flight"):
        eng.submit(Request(rid=0, prompt=[3], max_new=2))
    h_again = eng.submit(a)                   # same OBJECT: idempotent
    assert h_again and eng.scheduler.pending == 0
    _drain(eng)
    assert len(a.out) == 8                    # no duplicated admission

    done_req = Request(rid=5, prompt=[4], max_new=1)
    assert eng.serve([done_req])["done"]
    h = eng.submit(done_req, on_token=lambda t: None)
    assert not h
    assert not eng._callbacks                 # nothing leaked


def test_reentrant_submit_from_on_token_cannot_steal_slot():
    """Review pin: submit() from inside an on_token callback while the
    outer admission's slot is not yet recorded reports backpressure
    instead of stealing the slot."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    inner = Request(rid=1, prompt=[9, 8], max_new=2)
    results = []

    def cb(tok):
        if not results:
            results.append(eng.submit(inner))

    a = Request(rid=0, prompt=[1, 2, 3], max_new=4)
    h_a = eng.submit(a, on_token=cb)
    assert h_a and not results[0]             # inner submit backpressured
    assert eng.slots[0] is a                  # A kept its slot
    assert not inner.done and inner.out == []
    h_inner = eng.submit(inner)               # plain retry admits cleanly
    assert h_inner
    _drain(eng)
    assert a.done and len(a.out) == 4
    assert inner.done and len(inner.out) == 2

    ref_eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    ref = Request(rid=0, prompt=[1, 2, 3], max_new=4)
    assert ref_eng.serve([ref])["done"]
    assert a.out == ref.out                   # A's stream uncorrupted


def test_serve_path_rejects_live_rid_collision():
    """Review pin: the scheduler admission path enforces the same
    unique-live-rid rule as submit(), without wedging the engine."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    a = Request(rid=0, prompt=[1, 2], max_new=20)
    assert eng.submit(a)
    clash = Request(rid=0, prompt=[3, 4], max_new=2)
    with pytest.raises(ValueError, match="in flight"):
        eng.serve([clash])
    assert eng.scheduler.pending == 0         # poison entry evicted
    _drain(eng)
    assert a.done and len(a.out) == 20        # A unharmed
    ok = Request(rid=0, prompt=[3, 4], max_new=2)   # rid free again now
    assert eng.serve([ok])["done"] and len(ok.out) == 2


def test_poison_entry_does_not_drop_committed_batch():
    """Review pin: when a poison scheduler entry raises mid-admission,
    requests already committed in the same tick are still prefilled (their
    reserved blocks must not leak and their callers must not hang)."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=32,
                                           paged=True, block_size=8))
    good = Request(rid=0, prompt=[1, 2, 3], max_new=3)
    bad = Request(rid=1, prompt=list(range(1, 40)), max_new=2)
    eng.scheduler.push(good)
    eng.scheduler.push(bad)
    with pytest.raises(ValueError):
        eng.step()
    assert eng.scheduler.pending == 0
    assert good.out                           # the committed batch ran
    _drain(eng)
    assert good.done and len(good.out) == 3
    assert eng.allocator.used_blocks == 0     # exact accounting after all


def test_no_double_admission_for_queued_request():
    """Review pin: a request left queued (serve() hit max_ticks) and then
    admitted directly via submit()/tokens() claims its own queue entry —
    it can never hold two slots and emit a duplicated stream."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    req = Request(rid=0, prompt=[1, 2, 3], max_new=4)
    eng.scheduler.push(req)                   # as serve(max_ticks=0) leaves
    handle = eng.submit(req)
    assert handle
    assert eng.scheduler.pending == 0         # own entry claimed
    _drain(eng)
    assert len(req.out) == 4                  # exactly max_new, no dupes
    assert eng.slots == [None, None]


def test_direct_submit_does_not_leapfrog_queued_priority():
    """Review pin: submit() admissions go through the scheduler's fairness
    rules — queued equal-or-higher-priority work blocks a direct grab, a
    strictly-higher direct submit wins but ages the queue."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48))
    hog = Request(rid=0, prompt=[1, 2], max_new=30)
    assert eng.submit(hog)                    # slot 0 busy
    queued_hi = Request(rid=1, prompt=[3, 4], max_new=2, priority=1)
    eng.scheduler.push(queued_hi)
    low = Request(rid=2, prompt=[5, 6], max_new=2, priority=0)
    assert not eng.submit(low)                # free slot, but queue wins
    assert eng.scheduler.pending == 1
    hi2 = Request(rid=3, prompt=[7, 8], max_new=2, priority=2)
    assert eng.submit(hi2)                    # strictly higher: admits...
    entry = eng.scheduler.select()
    assert entry.req.rid == 1 and entry.passed == 1   # ...and ages queue
    _drain(eng, max_ticks=64)
    assert queued_hi.done and hi2.done


def test_priority_admission_order_under_contention():
    """End-to-end: with one slot and queued mixed priorities, the high
    class is admitted first — its TTFT ordering is what the bench gates."""
    cfg, params = _setup()
    eng = Engine(cfg, params, EngineConfig(max_batch=1, max_seq=48))
    reqs = [Request(rid=i, prompt=[i + 1, i + 2], max_new=2,
                    priority=(1 if i % 2 else 0))
            for i in range(6)]
    assert eng.serve(reqs)["done"]
    first_ts = {r.rid: r.token_ts[0] for r in reqs}
    hi = [first_ts[r.rid] for r in reqs if r.priority == 1]
    lo = [first_ts[r.rid] for r in reqs if r.priority == 0]
    assert max(hi) < min(lo)                  # every high admitted first
