"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -e .[dev])")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.lut import NF4_CODEBOOK
from repro.kernels.flash_attention.ops import mha
from repro.kernels.luna_mm.ops import luna_matmul_f32_kernel, luna_mm_codes
from repro.kernels.luna_mm.ref import luna_mm_ref
from repro.kernels.lut_gemm.lut_gemm import lut_gemm
from repro.kernels.lut_gemm.ops import codebook_quantize, nf4_matmul_kernel
from repro.kernels.lut_gemm.ref import lut_gemm_ref

MODES = ["conventional", "dc", "opt_dc", "approx_dc", "approx_dc2"]


# ---------------------------------------------------------------------------
# luna_mm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("shape", [(8, 8, 8), (64, 96, 40), (128, 256, 128),
                                   (33, 17, 9), (1, 300, 5)])
def test_luna_mm_shapes(mode, shape):
    m, k, n = shape
    rng = np.random.default_rng(hash((mode, shape)) % 2**32)
    y = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int8)
    got = luna_mm_codes(y, w, mode=mode, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(luna_mm_ref(y, w, mode)))


@given(m=st.integers(1, 40), k=st.integers(1, 80), n=st.integers(1, 24),
       mode=st.sampled_from(MODES), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_luna_mm_property(m, k, n, mode, seed):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.integers(0, 16, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 16, (k, n)), jnp.int8)
    got = luna_mm_codes(y, w, mode=mode, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(luna_mm_ref(y, w, mode)))


@pytest.mark.parametrize("mode", ["opt_dc", "approx_dc", "approx_dc2"])
def test_luna_mm_f32_wrapper_matches_library(mode):
    """Kernel float path == core library float path (same quant algebra)."""
    from repro.core.quant import luna_matmul_f32
    from repro.core.luna import LunaMode
    lm = {"opt_dc": LunaMode.OPT_DC, "approx_dc": LunaMode.APPROX_DC,
          "approx_dc2": LunaMode.APPROX_DC2}[mode]
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(24, 48)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(48, 16)), jnp.float32)
    got = luna_matmul_f32_kernel(x, w, mode=mode, interpret=True)
    ref = luna_matmul_f32(x, w, lm, bits=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_luna_mm_approx_halves_matmul_work():
    """ApproxD&C's TPU payoff: the kernel does 1 digit-plane matmul not 2.

    Verified structurally: approx == exact with the low plane zeroed."""
    rng = np.random.default_rng(11)
    y = jnp.asarray(rng.integers(0, 16, (32, 64)), jnp.int8)
    w = jnp.asarray(rng.integers(0, 16, (64, 16)), jnp.int8)
    approx = luna_mm_codes(y, w, mode="approx_dc", interpret=True)
    y_hi_only = jnp.asarray((np.asarray(y) >> 2) << 2, jnp.int8)
    exact_hi = luna_mm_codes(y_hi_only, w, mode="opt_dc", interpret=True)
    np.testing.assert_array_equal(np.asarray(approx), np.asarray(exact_hi))


# ---------------------------------------------------------------------------
# lut_gemm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(16, 32, 16), (48, 96, 33), (8, 8, 8),
                                   (128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_gemm_shapes_dtypes(shape, dtype):
    m, k, n = shape
    rng = np.random.default_rng(hash(shape) % 2**32)
    x = jnp.asarray(rng.normal(size=(m, k)), dtype)
    w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    got = nf4_matmul_kernel(x.astype(jnp.float32), w, interpret=True)
    cb = jnp.asarray(NF4_CODEBOOK)
    codes, scale = codebook_quantize(w, cb)
    ref = lut_gemm_ref(x.astype(jnp.float32), codes, cb, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_lut_gemm_arbitrary_codebook():
    """Programmability: any 16-entry table, not just NF4/uniform."""
    rng = np.random.default_rng(3)
    cb = jnp.asarray(np.sort(rng.normal(size=16)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 16, (64, 32)), jnp.int8)
    scale = jnp.asarray(rng.uniform(0.5, 2.0, 32), jnp.float32)
    x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    got = lut_gemm(x, codes, cb, scale, bm=16, bn=32, bk=64, interpret=True)
    ref = lut_gemm_ref(x, codes, cb, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,s,h,hkv,d", [
    (1, 128, 2, 2, 16), (2, 256, 4, 2, 32), (1, 512, 8, 1, 64),
])
def test_flash_vs_ref(b, s, h, hkv, d, causal):
    rng = np.random.default_rng(hash((b, s, h, hkv, d, causal)) % 2**32)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    sm = 1.0 / np.sqrt(d)
    got = mha(q, k, v, sm_scale=sm, causal=causal, use_flash=True,
              interpret=True)
    ref = mha(q, k, v, sm_scale=sm, causal=causal, use_flash=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5,
                               atol=2e-5)


def test_flash_bf16():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.bfloat16)
    got = mha(q, k, v, sm_scale=0.17, use_flash=True, interpret=True)
    ref = mha(q.astype(jnp.float32), k.astype(jnp.float32),
              v.astype(jnp.float32), sm_scale=0.17, use_flash=False)
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(ref), rtol=2e-2, atol=2e-2)
