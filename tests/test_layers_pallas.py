"""LunaDense with use_pallas=True routes through the Pallas kernel and
matches the pure-library path (the layer-level integration of the kernels)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layers import QuantConfig, quant_matmul


@pytest.mark.parametrize("mode", ["luna_dc", "luna_approx", "luna_approx2"])
def test_use_pallas_matches_library(mode):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 24)), jnp.float32)
    lib = quant_matmul(x, w, QuantConfig(mode=mode))
    kern = quant_matmul(x, w, QuantConfig(mode=mode, use_pallas=True))
    np.testing.assert_allclose(np.asarray(kern), np.asarray(lib),
                               rtol=1e-5, atol=1e-5)


def test_use_pallas_in_model_forward():
    """A reduced transformer forward with kernel-backed LUNA projections."""
    from repro.models.registry import get_config, get_model
    cfg = get_config("yi-9b").reduced(
        quant=QuantConfig(mode="luna_approx", use_pallas=True))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)))
    hidden, _, _ = model.forward(params, toks)
    assert np.isfinite(np.asarray(hidden, np.float32)).all()

    # equals the library path bit-for-bit at the loss level
    cfg_lib = get_config("yi-9b").reduced(
        quant=QuantConfig(mode="luna_approx", use_pallas=False))
    model_lib = get_model(cfg_lib)
    l_k, _ = model.loss(params, {"tokens": toks, "labels": toks})
    l_l, _ = model_lib.loss(params, {"tokens": toks, "labels": toks})
    assert abs(float(l_k) - float(l_l)) < 1e-3
