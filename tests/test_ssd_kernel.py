"""SSD chunk-scan Pallas kernel vs oracles (shape sweep, interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_chunked_kernel
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.ssm import _ssd_chunked


def _inputs(B, S, H, P, G, N, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32),
            jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32),
            jnp.asarray(-rng.uniform(0.5, 2.0, H), jnp.float32),
            jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 128, 2, 8, 1, 8, 64),
    (2, 256, 4, 16, 2, 8, 64),
    (1, 256, 4, 32, 1, 16, 128),
    (2, 512, 2, 16, 2, 32, 128),
])
def test_ssd_kernel_matches_chunked_jnp(B, S, H, P, G, N, chunk):
    x, dt, a, b, c = _inputs(B, S, H, P, G, N, seed=B + S)
    y_k, fs_k = ssd_chunked_kernel(x, dt, a, b, c, chunk=chunk,
                                   interpret=True)
    y_j, fs_j = _ssd_chunked(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs_k), np.asarray(fs_j),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_sequential_recurrence():
    """The chunked algorithm == the token-by-token state recurrence."""
    B, S, H, P, G, N = 2, 256, 4, 16, 2, 8
    x, dt, a, b, c = _inputs(B, S, H, P, G, N)
    y_k, fs_k = ssd_chunked_kernel(x, dt, a, b, c, chunk=64, interpret=True)
    hg = H // G
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    bf = jnp.repeat(b, hg, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    cf = jnp.repeat(c, hg, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    y_r, fs_r = ssd_ref(xf, dtf, jnp.tile(a, B), bf, cf)
    y_r = y_r.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=5e-4, atol=5e-4)
