"""SSD chunk-scan Pallas kernel vs oracles (shape sweep, interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.ops import ssd_chunked_kernel
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.models.ssm import _ssd_chunked


def _inputs(B, S, H, P, G, N, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32),
            jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32),
            jnp.asarray(-rng.uniform(0.5, 2.0, H), jnp.float32),
            jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 128, 2, 8, 1, 8, 64),
    (2, 256, 4, 16, 2, 8, 64),
    (1, 256, 4, 32, 1, 16, 128),
    (2, 512, 2, 16, 2, 32, 128),
])
def test_ssd_kernel_matches_chunked_jnp(B, S, H, P, G, N, chunk):
    x, dt, a, b, c = _inputs(B, S, H, P, G, N, seed=B + S)
    y_k, fs_k = ssd_chunked_kernel(x, dt, a, b, c, chunk=chunk,
                                   interpret=True)
    y_j, fs_j = _ssd_chunked(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs_k), np.asarray(fs_j),
                               rtol=1e-4, atol=1e-4)


def test_ssd_resume_matches_whole_sequence():
    """State continuation: scanning a sequence in two halves, feeding the
    first half's final state as the second half's initial state, equals one
    whole-sequence scan — for the jnp path AND the pallas kernel (the
    contract chunked prefill rests on)."""
    B, S, H, P, G, N, chunk = 2, 256, 4, 16, 2, 8, 64
    x, dt, a, b, c = _inputs(B, S, H, P, G, N, seed=7)
    y_w, fs_w = _ssd_chunked(x, dt, a, b, c, chunk)
    h = S // 2
    y1, fs1 = _ssd_chunked(x[:, :h], dt[:, :h], a, b[:, :h], c[:, :h], chunk)
    y2, fs2 = _ssd_chunked(x[:, h:], dt[:, h:], a, b[:, h:], c[:, h:], chunk,
                           initial_state=fs1)
    np.testing.assert_allclose(np.concatenate([y1, y2], 1), np.asarray(y_w),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fs2), np.asarray(fs_w),
                               rtol=2e-4, atol=2e-4)
    yk, fsk = ssd_chunked_kernel(x[:, h:], dt[:, h:], a, b[:, h:], c[:, h:],
                                 chunk=chunk, interpret=True,
                                 initial_state=fs1)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fsk), np.asarray(fs2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("L", [77, 128, 1])
def test_ssd_mask_matches_exact_prefix(L):
    """A right-padded masked scan carries exactly the valid prefix's state
    (pad positions are inert), incl. valid lengths off the chunk grid."""
    B, S, H, P, G, N, chunk = 2, 128, 4, 16, 2, 8, 32
    x, dt, a, b, c = _inputs(B, S, H, P, G, N, seed=11)
    mask = jnp.broadcast_to(jnp.arange(S)[None, :] < L, (B, S))
    y_m, fs_m = _ssd_chunked(x, dt, a, b, c, chunk, mask=mask)
    y_e, fs_e = _ssd_chunked(x[:, :L], dt[:, :L], a, b[:, :L], c[:, :L],
                             chunk)
    np.testing.assert_allclose(np.asarray(y_m)[:, :L], np.asarray(y_e),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fs_m), np.asarray(fs_e),
                               rtol=2e-4, atol=2e-4)
    yk, fsk = ssd_chunked_kernel(x, dt, a, b, c, chunk=chunk,
                                 interpret=True, mask=mask)
    np.testing.assert_allclose(np.asarray(fsk), np.asarray(fs_m),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_matches_sequential_recurrence():
    """The chunked algorithm == the token-by-token state recurrence."""
    B, S, H, P, G, N = 2, 256, 4, 16, 2, 8
    x, dt, a, b, c = _inputs(B, S, H, P, G, N)
    y_k, fs_k = ssd_chunked_kernel(x, dt, a, b, c, chunk=64, interpret=True)
    hg = H // G
    xf = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(B * H, S)
    bf = jnp.repeat(b, hg, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    cf = jnp.repeat(c, hg, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, N)
    y_r, fs_r = ssd_ref(xf, dtf, jnp.tile(a, B), bf, cf)
    y_r = y_r.reshape(B, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=5e-4, atol=5e-4)
