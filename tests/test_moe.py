"""MoE dispatch: routing correctness, capacity semantics, EP-friendliness."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.moe import _capacity, init_moe, moe_ffn


def _cfg(e=8, k=2, cap=8.0, shared=0, d=16, dff=8):
    # capacity_factor chosen high so nothing drops unless the test wants it
    return ModelConfig(
        name="t", family="moe", num_layers=2, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=dff, vocab_size=64, head_dim=8,
        moe=MoEConfig(num_experts=e, num_shared=shared, top_k=k,
                      d_expert=dff, capacity_factor=cap))


def test_moe_matches_dense_expert_sum():
    """With capacity high enough to route everything, the grouped dispatch
    equals the naive 'every token through its top-k experts' computation."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    out, aux = moe_ffn(params, x, cfg)

    # naive reference
    xt = x.reshape(-1, cfg.d_model)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    ref = jnp.zeros_like(xt)
    for t in range(xt.shape[0]):
        for j in range(cfg.moe.top_k):
            e = int(top_e[t, j])
            h = (jax.nn.silu(xt[t] @ params["w_gate"][e])
                 * (xt[t] @ params["w_up"][e]))
            ref = ref.at[t].add(top_p[t, j] * (h @ params["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0


def test_moe_capacity_drops_overflow():
    """With capacity_factor ~0, most tokens drop -> output ~ only shared."""
    cfg = _cfg(cap=0.01, shared=1)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    out, _ = moe_ffn(params, x, cfg)
    # shared-expert-only reference
    sp = params["shared"]
    xt = x.reshape(-1, cfg.d_model)
    shared_out = (jax.nn.silu(xt @ sp["w_gate"]) * (xt @ sp["w_up"])
                  ) @ sp["w_down"]
    # a few tokens still fit in the minimal capacity, so compare loosely:
    diff = np.abs(np.asarray(out.reshape(-1, cfg.d_model) - shared_out))
    routed_rows = (diff.max(axis=1) > 1e-6).sum()
    cap = _capacity(64, cfg)
    assert routed_rows <= cfg.moe.num_experts * cap


def test_moe_decode_single_group():
    """s==1 folds batch into one group: capacity ~ B*k/E not B."""
    cfg = _cfg(e=8, k=2)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 1, cfg.d_model))
    out, _ = moe_ffn(params, x, cfg)
    assert out.shape == (16, 1, cfg.d_model)
    assert np.isfinite(np.asarray(out)).all()


def test_moe_aux_loss_balanced_vs_collapsed():
    """Fully collapsed routing hits the E*coef ceiling of the Switch aux
    loss and exceeds whatever a random router produces (random init on a
    small d_model is only ROUGHLY balanced, so the old fixed 1.5x margin
    against it was flaky — the collapse ceiling is exact)."""
    cfg = _cfg(e=4, k=1)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    # positive activations so a positive router column collapses routing
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3),
                                  (4, 32, cfg.d_model))) + 0.1
    _, aux_norm = moe_ffn(params, x, cfg)
    collapsed = dict(params)
    collapsed["router"] = jnp.zeros_like(params["router"]).at[:, 0].set(10.0)
    _, aux_coll = moe_ffn(collapsed, x, cfg)
    e, coef = cfg.moe.num_experts, cfg.moe.aux_loss_coef
    np.testing.assert_allclose(float(aux_coll), e * coef, rtol=1e-3)
    assert float(aux_norm) < float(aux_coll)
    # any routing is at least the balanced optimum, coef (= E * (1/E)^2 * E)
    assert float(aux_norm) >= coef * 0.99


def test_moe_gradients_flow_to_router_and_experts():
    cfg = _cfg()
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, cfg.d_model))

    def loss(p):
        out, aux = moe_ffn(p, x, cfg)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_gate"]).sum()) > 0
    assert float(jnp.abs(g["w_down"]).sum()) > 0
