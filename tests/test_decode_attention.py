"""Sharded flash-decode attention == dense decode (multi-device subprocess)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

GQA_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.registry import get_config, get_model
    from repro.parallel.act_sharding import activation_sharding
    from dataclasses import replace

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = get_config(%(arch)r).reduced(dtype="float32", attn_impl="full")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 8)))

    outs = {}
    for mode in ("dense", "sharded", "grouped"):
        prec = "bf16_grouped" if mode == "grouped" else "f32"
        cfg = replace(base, decode_attn="sharded" if mode == "grouped"
                      else mode, decode_attn_precision=prec)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        state = model.init_cache(2, 16)
        step = jax.jit(model.decode_step)
        seq = []
        ctx = activation_sharding(mesh) if mode != "dense" else None
        import contextlib
        with mesh, (ctx or contextlib.nullcontext()):
            for i in range(8):
                lg, state = step(params, toks[:, i:i+1], state, jnp.int32(i))
                seq.append(np.asarray(lg[:, 0], np.float32))
        outs[mode] = np.stack(seq)
    scale = np.abs(outs["dense"]).max()
    for mode in ("sharded", "grouped"):
        diff = np.abs(outs["dense"] - outs[mode]).max()
        assert diff / scale < 2e-4, (mode, diff, scale)
    print("OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
def test_sharded_decode_matches_dense(arch):
    """Flash-decode shard_map path == dense path, teacher-forced 8 steps.

    yi-9b: GQA path; deepseek-v2-lite: MLA compressed-cache path.
    Reduced configs have kv heads < model axis -> caches are seq-sharded,
    exactly the production regime the optimization targets.
    """
    code = GQA_CODE % {"arch": arch}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


PAGED_CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.registry import get_config, get_model
    from repro.parallel.act_sharding import activation_sharding
    from dataclasses import replace
    import contextlib

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = get_config(%(arch)r).reduced(dtype="float32", attn_impl="full")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 8)))
    # 2 rows x 4 blocks of 4 tokens; +garbage block, pool padded to a
    # multiple of the 4-way model axis
    bt = jnp.asarray([[1, 2, 3, 4], [5, 6, 7, 8]], jnp.int32)

    outs = {}
    for mode in ("dense", "paged", "paged_sharded"):
        cfg = replace(base, decode_attn="sharded" if mode == "paged_sharded"
                      else "dense")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        paged = mode != "dense"
        from repro.models.common import CacheSpec
        state = (model.init_cache(2, 16, spec=CacheSpec(4, 12))
                 if paged else model.init_cache(2, 16))
        step = jax.jit(model.decode_step, static_argnames=())
        ctx = activation_sharding(mesh) if mode == "paged_sharded" else None
        seq = []
        with mesh, (ctx or contextlib.nullcontext()):
            for i in range(8):
                idx = jnp.full((2,), i, jnp.int32)
                if paged:
                    lg, state = step(params, toks[:, i:i+1], state, idx,
                                     tables=bt)
                else:
                    lg, state = step(params, toks[:, i:i+1], state, idx)
                seq.append(np.asarray(lg[:, 0], np.float32))
        outs[mode] = np.stack(seq)
    scale = np.abs(outs["dense"]).max()
    for mode in ("paged", "paged_sharded"):
        diff = np.abs(outs["dense"] - outs[mode]).max()
        assert diff / scale < 2e-4, (mode, diff, scale)
    print("OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-v2-lite-16b"])
def test_paged_decode_matches_dense(arch):
    """Block-table decode == dense decode, local and under the shard_map
    flash-decode path (pool block-sharded over the model axis)."""
    code = PAGED_CODE % {"arch": arch}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=ROOT, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
