"""LUT-quantized decode hot path (EngineConfig(quant=...)).

The load-bearing pins:
  * quant=None is token-identical to the pre-quant engine (the decode tree
    IS the prefill tree — same object);
  * the D&C Pallas kernel, its jnp ref, and the engine's jnp decode path
    agree bit-for-bit on the same frozen weights;
  * quant="lut4" and quant="int4" emit identical tokens (two evaluation
    strategies of one affine grid — the paper's D&C argument);
  * quant="nf4" (non-affine: least-squares D&C + per-code residual
    correction) emits tokens identical to the direct full-table NF4
    dequant oracle, and its Pallas kernel is BITWISE-equal to the jnp ref
    on shared frozen tables;
  * quant="nf4p" (pruned residual sub-table) saves table bytes and stays
    above the documented token-agreement threshold vs unpruned nf4;
  * dc_decompose_codebook is least-squares-optimal (property test);
  * quantized greedy decode stays within the documented accuracy bound on
    the fig13 harness, and agrees with bf16 decode above threshold;
  * quant composes with paged=True + prefix_cache (warm == cold tokens).
"""
import importlib.util
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.lut import (NF4_CODEBOOK, dc_decompose_codebook,
                            prune_residual, residual_table_bytes,
                            scatter_residual)
from repro.core.quant import (NF4P_PRUNE_THRESHOLD, QuantizedWeight,
                              quantize_decode_params, quantize_weight)
from repro.kernels.lut_gemm.lut_gemm import lut_gemm_dc_res
from repro.kernels.lut_gemm.ops import lut4_matmul_kernel, quantized_matmul
from repro.kernels.lut_gemm.ref import lut_gemm_dc_ref, lut_gemm_dc_res_ref
from repro.models.registry import get_config, get_model
from repro.serve.config import EngineConfig
from repro.serve.engine import Engine, Request

MIXED_LENS = (3, 9, 5)


def _setup(arch="yi-9b", **over):
    cfg = get_config(arch).reduced(dtype="float32", attn_impl="full", **over)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, params


def _prompts(cfg, lens=MIXED_LENS, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, n).tolist() for n in lens]


def _serve(cfg, params, prompts, max_new=8, **conf):
    eng = Engine(cfg, params,
                 EngineConfig(max_batch=len(prompts), max_seq=48, **conf))
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    assert eng.serve(reqs)["done"]
    return [r.out for r in reqs], eng


# ---------------------------------------------------------------------------
# quant=None token identity
# ---------------------------------------------------------------------------

def test_quant_none_is_token_identical_and_aliases_params():
    """Acceptance pin: the default engine and an explicit quant=None engine
    emit the same tokens, and the decode tree IS the param tree (no copy,
    no transform — the strongest possible identity guarantee)."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    base, eng_default = _serve(cfg, params, prompts)
    none, eng_none = _serve(cfg, params, prompts, quant=None)
    assert base == none
    assert eng_default.decode_params is eng_default.params
    assert eng_none.decode_params is eng_none.params


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------

def test_lut_gemm_dc_pallas_matches_ref_and_jnp_path():
    """The D&C Pallas kernel (interpret), the jnp oracle, and the engine's
    decode-path matmul agree on identical frozen weights."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    qw = quantize_weight(w, "lut_dc")
    ref = lut_gemm_dc_ref(x, qw.codes, qw.hi_tab, qw.lo_tab,
                          qw.zero_point, qw.scale)
    pallas = lut4_matmul_kernel(x, w, interpret=True)
    jnp_path = quantized_matmul(x, qw)
    np.testing.assert_allclose(np.asarray(pallas), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(jnp_path), np.asarray(ref))


def test_dc_decomposition_exact_for_affine_free_for_nf4():
    """Paper Figs 2/3: an affine 16-entry LUT splits EXACTLY into two
    4-entry sub-tables; the non-linear NF4 table pays a nonzero residual —
    the capacity cost of the 6-vs-15-select area saving."""
    uniform = jnp.arange(16, dtype=jnp.float32) * 0.37 - 2.1
    hi, lo, res = dc_decompose_codebook(uniform)
    assert float(jnp.max(jnp.abs(res))) < 1e-5
    rebuilt = hi[:, None] + lo[None, :]
    np.testing.assert_allclose(np.asarray(rebuilt.reshape(-1)),
                               np.asarray(uniform), rtol=1e-5, atol=1e-5)
    _, _, res_nf4 = dc_decompose_codebook(jnp.asarray(NF4_CODEBOOK))
    assert float(jnp.max(jnp.abs(res_nf4))) > 0.05


def test_nf4_dc_res_pallas_bitwise_equals_ref():
    """The bitwise-parity contract: on the SAME frozen tables (quantize
    once, eagerly — the engine's freeze-at-construction discipline) the
    residual-corrected D&C Pallas kernel and its jnp ref agree bit-for-bit
    at every tiling, because they execute the identical operation order
    (6-select sum, residual gather, zero-point pre-matmul, scale in the
    epilogue)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    qw = quantize_weight(w, "nf4_dc")
    ref = lut_gemm_dc_res_ref(x, qw.codes, qw.hi_tab, qw.lo_tab,
                              qw.residual, qw.zero_point, qw.scale)
    for bn in (8, 16, 48):
        pallas = lut_gemm_dc_res(x, qw.codes, qw.hi_tab, qw.lo_tab,
                                 qw.residual, qw.zero_point, qw.scale,
                                 bm=8, bn=bn, bk=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(pallas), np.asarray(ref),
                                      err_msg=f"bn={bn}")
    # and the engine's jnp decode path lands within float-rounding of both
    jnp_path = quantized_matmul(x, qw)
    np.testing.assert_allclose(np.asarray(jnp_path), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_nf4_dc_matches_direct_dequant_weights():
    """Residual-corrected D&C reconstructs the NF4 codebook exactly up to
    float rounding: the nf4_dc and nf4_dequant kernels produce the same
    effective weights (and the pruned variant's error is bounded)."""
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(96, 32)), jnp.float32)
    eye = jnp.eye(96, dtype=jnp.float32)
    w_dc = quantized_matmul(eye, quantize_weight(w, "nf4_dc"))
    w_direct = quantized_matmul(eye, quantize_weight(w, "nf4_dequant"))
    np.testing.assert_allclose(np.asarray(w_dc), np.asarray(w_direct),
                               rtol=1e-5, atol=1e-5)
    w_p = quantized_matmul(
        eye, quantize_weight(w, "nf4_dc", NF4P_PRUNE_THRESHOLD))
    mae = float(jnp.abs(w_p - w_dc).mean())
    assert 0 < mae < 0.05, mae   # pruning costs something, but bounded


def test_quantized_weight_slices_under_scan():
    """Scan-stacked containers: every array child carries the leading L
    axis and lax.scan slices them per layer like float leaves."""
    rng = np.random.default_rng(4)
    ws = jnp.asarray(rng.normal(size=(3, 32, 16)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 32)), jnp.float32)
    qs = quantize_weight(ws, "lut_dc")
    assert qs.codes.shape == (3, 32, 16) and qs.scale.shape == (3, 16)
    assert qs.hi_tab.shape == (3, 4)

    def body(c, qwi):
        return c, quantized_matmul(x, qwi)

    _, ys = jax.lax.scan(body, 0, qs)
    per_layer = jnp.stack([
        quantized_matmul(x, jax.tree.map(lambda a: a[i], qs))
        for i in range(3)])
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(per_layer))


# ---------------------------------------------------------------------------
# engine behavior under quant
# ---------------------------------------------------------------------------

def test_lut4_and_int4_tokens_identical():
    """Two evaluation strategies of the same affine grid: the D&C
    sub-table LUT and direct dequant must emit identical tokens."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    lut, _ = _serve(cfg, params, prompts, quant="lut4")
    i4, _ = _serve(cfg, params, prompts, quant="int4")
    assert lut == i4


def test_quantized_greedy_agreement_above_threshold():
    """Accuracy bound on served tokens: prefill is full precision so every
    request's FIRST token matches bf16 exactly; overall greedy agreement
    stays above threshold (random-init reduced model — trained weights
    agree far more, see docs/quantization.md and the fig13 bound)."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    base, _ = _serve(cfg, params, prompts)
    lut, _ = _serve(cfg, params, prompts, quant="lut4")
    for b, q in zip(base, lut):
        assert b[0] == q[0]                       # prefill token: exact
    agree = sum(a == b for o1, o2 in zip(base, lut)
                for a, b in zip(o1, o2))
    total = sum(len(o) for o in base)
    assert agree / total >= 0.5, (agree, total)


def test_nf4_tokens_identical_to_direct_dequant_oracle():
    """Acceptance pin: an nf4 engine (6-select D&C + residual correction)
    emits exactly the tokens of an engine whose decode tree is the direct
    full-table NF4 dequant oracle (15 selects) — the D&C split plus
    residual loses nothing.  Prefill stays full precision, so the first
    token also matches bf16 exactly."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    base, _ = _serve(cfg, params, prompts)
    nf4, _ = _serve(cfg, params, prompts, quant="nf4")
    eng = Engine(cfg, params, EngineConfig(max_batch=len(prompts),
                                           max_seq=48, quant="nf4"))
    eng.decode_params = quantize_decode_params(params, "nf4_direct")
    reqs = [Request(rid=i, prompt=p, max_new=8)
            for i, p in enumerate(prompts)]
    assert eng.serve(reqs)["done"]
    assert nf4 == [r.out for r in reqs]
    assert [o[0] for o in nf4] == [o[0] for o in base]


def test_nf4p_pruned_decode_saves_bytes_within_agreement():
    """The pruned-residual engine: table bytes strictly saved, and served
    tokens stay above the agreement threshold vs unpruned nf4 (random-init
    reduced model — the bound is deliberately loose)."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    nf4, _ = _serve(cfg, params, prompts, quant="nf4")
    nf4p, eng = _serve(cfg, params, prompts, quant="nf4p")
    assert [o[0] for o in nf4] == [o[0] for o in nf4p]   # prefill exact
    agree = sum(a == b for o1, o2 in zip(nf4, nf4p)
                for a, b in zip(o1, o2))
    total = sum(len(o) for o in nf4)
    assert agree / total >= 0.4, (agree, total)
    # the pruned residual really is sparse, and sparse storage is smaller
    _, _, res = dc_decompose_codebook(jnp.asarray(NF4_CODEBOOK))
    kept_idx, kept_val = prune_residual(res, NF4P_PRUNE_THRESHOLD)
    assert 0 < int(kept_idx.shape[0]) < 16
    dense, pruned = residual_table_bytes(int(kept_idx.shape[0]))
    assert pruned < dense
    # scatter rebuilds the pruned table the engine actually decodes with
    leaf = jax.tree.leaves(
        eng.decode_params,
        is_leaf=lambda x: isinstance(x, QuantizedWeight))
    qws = [x for x in leaf if isinstance(x, QuantizedWeight)]
    assert qws and all(q.kernel == "nf4_dc" for q in qws)
    want = scatter_residual(kept_idx, kept_val)
    got = qws[0].residual
    np.testing.assert_allclose(
        np.asarray(got).reshape(-1, 16)[0], np.asarray(want),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# dc_decompose_codebook optimality (property tests)
#
# With ``hypothesis`` installed (the ``dev`` extra) these are real
# property tests; without it (this image cannot pip install) the same
# properties run over a deterministic seeded sweep — the checks are
# identical, only the example generator differs.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                       # pragma: no cover
    HAVE_HYPOTHESIS = False


def _check_affine_exact(a: float, b: float) -> None:
    """EVERY affine codebook c[q] = a*q + b splits exactly into HI/LO
    sub-tables (zero residual) — the paper's D&C applies to the whole
    affine family, not just the uniform int4 grid."""
    cb = a * jnp.arange(16, dtype=jnp.float32) + b
    hi, lo, res = dc_decompose_codebook(cb)
    scale = max(1.0, abs(a) * 16 + abs(b))
    assert float(jnp.max(jnp.abs(res))) <= 1e-5 * scale
    rebuilt = (hi[:, None] + lo[None, :]).reshape(-1)
    np.testing.assert_allclose(np.asarray(rebuilt), np.asarray(cb),
                               rtol=1e-5, atol=1e-5 * scale)


def _check_ls_optimal(cb_vals, dh: int, dl: int, eps: float) -> None:
    """No perturbation of a single HI or LO entry reduces the residual
    norm — dc_decompose_codebook's split is the least-squares optimum over
    all additive (row value + column value) decompositions."""
    cb = jnp.asarray(cb_vals, jnp.float32)
    hi, lo, res = dc_decompose_codebook(cb)
    base = float(jnp.sum(res ** 2))
    hi_p = hi.at[dh].add(eps)
    lo_p = lo.at[dl].add(eps)
    for h, l in ((hi_p, lo), (hi, lo_p)):
        res_p = cb - (h[:, None] + l[None, :]).reshape(-1)
        assert float(jnp.sum(res_p ** 2)) >= base - 1e-5


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(a=st.floats(-4, 4, allow_nan=False, allow_infinity=False),
           b=st.floats(-4, 4, allow_nan=False, allow_infinity=False))
    def test_dc_decomposition_exact_on_any_affine_grid(a, b):
        _check_affine_exact(a, b)

    @settings(max_examples=25, deadline=None)
    @given(cb_vals=st.lists(st.floats(-2, 2, allow_nan=False,
                                      allow_infinity=False, width=32),
                            min_size=16, max_size=16),
           dh=st.integers(0, 3), dl=st.integers(0, 3),
           eps=st.floats(-0.3, 0.3, allow_nan=False))
    def test_dc_decomposition_is_least_squares_optimal(cb_vals, dh, dl,
                                                       eps):
        _check_ls_optimal(cb_vals, dh, dl, eps)
else:
    def test_dc_decomposition_exact_on_any_affine_grid():
        rng = np.random.default_rng(11)
        _check_affine_exact(0.0, 0.0)
        _check_affine_exact(0.37, -2.1)
        for _ in range(25):
            a, b = rng.uniform(-4, 4, size=2)
            _check_affine_exact(float(a), float(b))

    def test_dc_decomposition_is_least_squares_optimal():
        rng = np.random.default_rng(12)
        _check_ls_optimal(np.asarray(NF4_CODEBOOK, np.float32), 0, 0, 0.1)
        for _ in range(25):
            cb = rng.uniform(-2, 2, size=16).astype(np.float32)
            dh, dl = rng.integers(0, 4, size=2)
            eps = float(rng.uniform(-0.3, 0.3))
            _check_ls_optimal(cb, int(dh), int(dl), eps)


def test_fig13_ptq_within_documented_bound():
    """The documented accuracy bound: the bf16-trained fig13 harness MLP,
    frozen to 4-bit QuantizedWeight leaves, stays within PTQ_MAE_BOUND of
    its own MAE — and both evaluation kernels land the same number."""
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "examples", "fig13_nn_accuracy.py")
    spec = importlib.util.spec_from_file_location("fig13", path)
    fig13 = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(fig13)
    mae_ideal, trained = fig13.train_one("ideal")
    mae_lut = fig13.ptq_mae(trained, "lut_dc")
    mae_int = fig13.ptq_mae(trained, "dequant")
    assert mae_lut <= mae_ideal * fig13.PTQ_MAE_BOUND, (mae_lut, mae_ideal)
    assert mae_lut == mae_int


def test_quant_composes_with_paged_and_prefix_cache():
    """Warm == cold under quant: a lut4 engine with paged blocks + prefix
    cache emits the same tokens for a shared-head prompt admitted cold
    (populating the tree) and warm (seeded from COW blocks)."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    head = rng.integers(1, cfg.vocab_size, 16).tolist()
    tail_a = rng.integers(1, cfg.vocab_size, 4).tolist()
    tail_b = rng.integers(1, cfg.vocab_size, 4).tolist()

    eng = Engine(cfg, params, EngineConfig(
        max_batch=2, max_seq=64, quant="lut4", paged=True, block_size=8,
        prefix_cache=True))
    cold = Request(rid=0, prompt=head + tail_a, max_new=6)
    assert eng.serve([cold])["done"]
    warm = Request(rid=1, prompt=head + tail_b, max_new=6)
    stats = eng.serve([warm])
    assert stats["done"] and stats["prefix_hits"] == 1

    # reference: same requests on a quant engine WITHOUT the prefix cache
    ref, _ = _serve(cfg, params, [head + tail_a, head + tail_b],
                    max_new=6, quant="lut4", paged=True, block_size=8)
    assert [cold.out, warm.out] == ref


def test_quantized_decode_all_served_families():
    """Every servable family decodes under lut4, with the exclusion rules
    honored: MoE routed experts and MLA's direct-use w_uk/w_uv stay float
    (they are einsum/reshape operands, not quant_matmul projections)."""
    for arch in ("deepseek-v2-lite-16b", "mamba2-1.3b", "zamba2-1.2b"):
        cfg, params = _setup(arch)
        qp = quantize_decode_params(params, "lut4")
        prompts = _prompts(cfg, lens=(4, 6))
        base, _ = _serve(cfg, params, prompts, max_new=4)
        lut, _ = _serve(cfg, params, prompts, max_new=4, quant="lut4")
        assert all(len(o) == 4 for o in lut), (arch, lut)
        assert [o[0] for o in base] == [o[0] for o in lut], arch
        if cfg.family == "moe":
            moe = qp["blocks"]["moe"]
            assert not isinstance(moe["w_up"], QuantizedWeight)
            assert isinstance(moe["shared"]["w_up"], QuantizedWeight)


def test_mla_direct_use_leaves_stay_float():
    """deepseek MLA consumes w_uk/w_uv via reshape+einsum — the tree
    quantizer must never touch them."""
    cfg, params = _setup("deepseek-v2-lite-16b")
    qp = quantize_decode_params(params, "lut4")
    attn = qp["blocks"]["attn"]
    assert not isinstance(attn["w_uk"], QuantizedWeight)
    assert not isinstance(attn["w_uv"], QuantizedWeight)
    assert isinstance(attn["w_dkv"], QuantizedWeight)


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------

def test_engine_config_quant_validation():
    with pytest.raises(ValueError, match="quant"):
        EngineConfig(quant="fp3")
    for mode in ("lut4", "int4", "nf4", "nf4p"):
        assert EngineConfig(quant=mode).quant == mode
    # "nf4_direct" is the test/fig13 oracle spelling, not an engine mode
    with pytest.raises(ValueError, match="quant"):
        EngineConfig(quant="nf4_direct")
    assert EngineConfig().quant is None


def test_engine_rejects_double_quantization():
    """Engine-level frozen 4-bit + model-level dynamic quant would
    quantize twice; the constructor refuses the combination."""
    from repro.core.layers import QuantConfig
    cfg, params = _setup(quant=QuantConfig(mode="luna_approx"))
    with pytest.raises(ValueError, match="twice"):
        Engine(cfg, params, EngineConfig(max_batch=1, max_seq=32,
                                         quant="lut4"))


def test_from_args_routes_shared_quant_flag():
    """The shared --quant flag: engine modes land on EngineConfig.quant,
    model-level spellings leave it None (the caller routes them into a
    QuantConfig)."""
    import argparse
    ap = argparse.ArgumentParser()
    EngineConfig.add_cli_args(ap)
    args = ap.parse_args(["--quant", "lut4"])
    assert EngineConfig.from_args(args).quant == "lut4"
    args = ap.parse_args(["--quant", "nf4"])
    assert EngineConfig.from_args(args).quant == "nf4"
    args = ap.parse_args(["--quant", "nf4p"])
    assert EngineConfig.from_args(args).quant == "nf4p"
    args = ap.parse_args(["--quant", "luna_approx"])
    assert EngineConfig.from_args(args).quant is None
    args = ap.parse_args([])
    assert EngineConfig.from_args(args).quant is None
