"""Speculative decoding: token-identity on every family + lifecycle edges.

The load-bearing pins:
  * speculative greedy output is TOKEN-IDENTICAL to non-speculative greedy
    for attention, SSM, and hybrid configs, both proposers, dense and
    paged substrates (the verifier's argmax IS the plain tick's argmax —
    drafts only change how many of them land per tick);
  * the accept/rollback machinery composes with the rest of the request
    lifecycle: preempt and cancel fired from an ``on_token`` callback
    mid-window, prefix-cache warm admissions, and the per-request
    acceptance accounting at retirement.
"""
import jax
import numpy as np
import pytest

from repro.models.registry import get_config, get_model
from repro.serve.config import EngineConfig
from repro.serve.engine import Engine, Request
from repro.serve.spec import NGramProposer, _prompt_lookup, accept_length


def _setup(arch="yi-9b", **over):
    cfg = get_config(arch).reduced(dtype="float32", attn_impl="full", **over)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, params


def _prompts(cfg, lens=(5, 11, 3)):
    rng = np.random.default_rng(0)
    return [rng.integers(1, cfg.vocab_size, n).tolist() for n in lens]


def _serve(cfg, params, prompts, max_new=8, **knobs):
    eng = Engine(cfg, params, EngineConfig(max_batch=3, max_seq=48, **knobs))
    reqs = [Request(rid=i, prompt=list(p), max_new=max_new)
            for i, p in enumerate(prompts)]
    assert eng.serve(reqs)["done"]
    return [r.out for r in reqs], eng


# --- host-side helpers -------------------------------------------------
def test_accept_length():
    assert accept_length([], [7]) == 0
    assert accept_length([4, 5], [4, 5, 6]) == 2
    assert accept_length([4, 9], [4, 5, 6]) == 1
    # agreements after the first mismatch are conditioned on a wrong
    # prefix and must not count
    assert accept_length([9, 5], [4, 5, 6]) == 0


def test_prompt_lookup():
    # longest suffix n-gram wins, most recent earlier occurrence
    assert _prompt_lookup([1, 2, 3, 9, 1, 2, 3], 2, 3, 1) == [9, 1]
    # budget caps the continuation
    assert _prompt_lookup([1, 2, 3, 9, 1, 2, 3], 1, 3, 1) == [9]
    # a match flush with the suffix has no continuation: back off to a
    # shorter n-gram rather than return nothing
    assert _prompt_lookup([5, 1, 2, 5, 9, 1, 2], 2, 3, 1) == [5, 9]
    # nothing repeats: no draft
    assert _prompt_lookup([1, 2, 3, 4], 3, 3, 1) == []


def test_ngram_proposer_respects_budget():
    prop = NGramProposer()
    req = Request(rid=0, prompt=[1, 2, 3, 1, 2], max_new=8)
    req.out = [3]
    drafts = prop.propose([req, None], [2, 4])
    assert drafts[1] == []
    assert len(drafts[0]) <= 2


# --- config surface ----------------------------------------------------
def test_spec_config_validation():
    from repro.serve.sampling import SamplingConfig
    with pytest.raises(ValueError, match="spec must be one of"):
        EngineConfig(spec="medusa")
    with pytest.raises(ValueError, match="spec_k"):
        EngineConfig(spec="ngram", spec_k=0)
    with pytest.raises(ValueError, match="greedy-only"):
        EngineConfig(spec="ngram",
                     sampling=SamplingConfig(mode="temperature",
                                             temperature=0.7))
    # greedy sampling (explicit or default) composes fine
    EngineConfig(spec="ngram", sampling=SamplingConfig(mode="greedy"))
    EngineConfig(spec="self_lut", spec_k=2)


# --- token identity, per family ----------------------------------------
@pytest.mark.parametrize("arch,paged", [
    ("yi-9b", False),          # attention, dense slab
    ("yi-9b", True),           # attention, paged pool
    ("mamba2-1.3b", False),    # pure SSM (recurrent re-commit path)
    ("zamba2-1.2b", False),    # hybrid, dense
    ("zamba2-1.2b", True),     # hybrid, split substrate
])
@pytest.mark.parametrize("mode", ["ngram", "self_lut"])
def test_spec_token_identity(arch, paged, mode):
    cfg, params = _setup(arch)
    prompts = _prompts(cfg)
    base, _ = _serve(cfg, params, prompts, paged=paged)
    out, eng = _serve(cfg, params, prompts, paged=paged, spec=mode)
    assert out == base
    m = eng.metrics
    assert m.spec_accepted + m.spec_rejected == m.spec_drafted
    if mode == "self_lut":
        # the LUT draft tree always proposes a full window
        assert m.spec_drafted > 0 and m.spec_ticks > 0


def test_spec_identity_moe_mla():
    """DeepSeek MLA attention + capacity-routed MoE: the verify window
    groups MoE dispatch by column so routing competition matches the
    plain per-tick fold."""
    cfg, params = _setup("deepseek-v2-lite-16b")
    prompts = _prompts(cfg)
    base, _ = _serve(cfg, params, prompts)
    out, eng = _serve(cfg, params, prompts, spec="self_lut")
    assert out == base
    assert eng.metrics.spec_drafted > 0


def test_spec_metrics_and_obs():
    cfg, params = _setup()
    prompts = _prompts(cfg)
    _, eng = _serve(cfg, params, prompts, spec="self_lut", trace=True)
    m = eng.metrics
    s = m.summary(3)
    assert s["spec_ticks"] == m.spec_ticks > 0
    assert 0.0 <= s["spec_acceptance"] <= 1.0
    assert s["spec_acceptance"] == m.spec_accepted / m.spec_drafted
    dump = eng.registry.dump()
    assert dump["engine_spec_accepted_per_window"]["series"]
    assert dump["engine_spec_tokens_per_request"]["series"]
    assert dump["engine_info"]["series"]
    (k, v), = dump["engine_info"]["series"].items()
    assert "spec" in k and "self_lut" in k
    # the per-request histogram observes once per kind per retired request
    series = dump["engine_spec_tokens_per_request"]["series"]
    counts = {k: s["count"] for k, s in series.items()}
    assert all(c == len(prompts) for c in counts.values()), counts
    names = {e.name for e in eng.tracer.events()}
    assert {"draft", "verify", "emit"} <= names


def test_spec_tokens_not_double_counted():
    """decode_tokens counts every emitted token exactly once (accepted
    drafts + corrections), so tok/s math stays honest under speculation."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    out, eng = _serve(cfg, params, prompts, spec="self_lut")
    # prefill emits token 0; every later token comes from exactly one
    # spec-tick emission (accepted draft or correction)
    assert eng.metrics.decode_tokens == sum(len(o) - 1 for o in out)


# --- lifecycle edges under speculation ----------------------------------
def test_spec_cancel_from_callback_mid_window():
    """An on_token callback on one request cancels ANOTHER active request
    mid-spec-tick: the cancelled row's teardown must not be undone by the
    remainder of the emit loop (no rollback/positions writes on a freed
    slot), and the survivor must finish token-identical."""
    cfg, params = _setup()
    prompts = _prompts(cfg, lens=(5, 7))
    base, _ = _serve(cfg, params, prompts[:1], max_new=8, spec="self_lut")

    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48,
                                           spec="self_lut"))
    r0 = Request(rid=0, prompt=list(prompts[0]), max_new=8)
    r1 = Request(rid=1, prompt=list(prompts[1]), max_new=8)
    fired = []

    def kill_r1(tok):
        if len(r0.out) == 3 and not fired:
            fired.append(True)
            assert eng.cancel(r1)

    h0 = eng.submit(r0, on_token=kill_r1)
    h1 = eng.submit(r1)
    assert h0 and h1
    for _ in range(64):
        if r0.done and r1.done:
            break
        eng.step()
    assert r0.done and r1.done and r1.cancelled
    assert r0.out == base[0]
    assert eng.metrics.cancelled == 1


def test_spec_preempt_racing_mid_verify():
    """Preempting an active request from a callback mid-spec-tick frees
    its slot inside the emit loop; the requeued request re-prefills its
    extended prompt and the continued stream is token-identical to never
    having been preempted (same pin the plain engine carries)."""
    cfg, params = _setup()
    prompts = _prompts(cfg, lens=(5, 7))
    base, _ = _serve(cfg, params, prompts, max_new=8, spec="self_lut")

    eng = Engine(cfg, params, EngineConfig(max_batch=3, max_seq=48,
                                           spec="self_lut"))
    r0 = Request(rid=0, prompt=list(prompts[0]), max_new=8)
    r1 = Request(rid=1, prompt=list(prompts[1]), max_new=8)
    fired = []

    def kick_r1(tok):
        if len(r0.out) == 3 and not fired:
            fired.append(True)
            eng.preempt(r1)

    h0 = eng.submit(r0, on_token=kick_r1)
    h1 = eng.submit(r1)
    assert h0 and h1
    for _ in range(64):
        if r0.done and r1.done:
            break
        eng.step()
    assert r0.done and r1.done
    assert fired and eng.metrics.preemptions == 1
    assert [r0.out, r1.out] == base


def test_spec_cancel_during_draft_window():
    """A request cancelled between submit and its first spec tick (i.e.
    while the proposer would still draft for it) is skipped cleanly."""
    cfg, params = _setup()
    prompts = _prompts(cfg, lens=(5, 7))
    eng = Engine(cfg, params, EngineConfig(max_batch=2, max_seq=48,
                                           spec="self_lut"))
    r0 = Request(rid=0, prompt=list(prompts[0]), max_new=8)
    r1 = Request(rid=1, prompt=list(prompts[1]), max_new=8)
    assert eng.submit(r0) and eng.submit(r1)
    assert eng.cancel(r1)
    for _ in range(32):
        if r0.done:
            break
        eng.step()
    base, _ = _serve(cfg, params, prompts[:1], max_new=8, spec="self_lut")
    assert r0.done and r0.out == base[0]


def test_spec_prefix_cache_warm_equals_cold():
    """spec x paged x prefix-cache: a warm admission sharing a cached
    prefix must stream token-identically to its own cold run."""
    cfg, params = _setup()
    rng = np.random.default_rng(3)
    shared = rng.integers(1, cfg.vocab_size, 24).tolist()
    p_a = shared + rng.integers(1, cfg.vocab_size, 4).tolist()
    p_b = shared + rng.integers(1, cfg.vocab_size, 6).tolist()

    def run(prompts, **kw):
        eng = Engine(cfg, params, EngineConfig(
            max_batch=2, max_seq=64, paged=True, prefix_cache=True,
            spec="self_lut", **kw))
        outs = []
        for i, p in enumerate(prompts):
            req = Request(rid=i, prompt=list(p), max_new=6)
            assert eng.serve([req])["done"]
            outs.append(req.out)
        return outs, eng

    cold_a, _ = run([p_a])
    cold_b, _ = run([p_b])
    warm, eng = run([p_a, p_b])
    assert warm == [cold_a[0], cold_b[0]]
    assert eng.metrics.prefix_hits >= 1


def test_spec_max_new_one_never_spec_ticks():
    """max_new=1 requests finish at admission; the spec path must not
    draft for (or emit beyond) them."""
    cfg, params = _setup()
    prompts = _prompts(cfg)
    base, _ = _serve(cfg, params, prompts, max_new=1)
    out, eng = _serve(cfg, params, prompts, max_new=1, spec="self_lut")
    assert out == base and all(len(o) == 1 for o in out)
    assert eng.metrics.spec_ticks == 0
