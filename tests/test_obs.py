"""Observability subsystem: registry/tracer units + engine integration.

The load-bearing pins:
  * ``EngineMetrics`` keeps its exact field/``since()``/``summary()``
    contracts with ``Engine.metrics`` now a live registry-backed view
    (reads, writes, ``+=``, and the bench's counter resets all work);
  * ``summary()`` reports 0.0 tok/s when no tokens moved (an empty run
    must not divide 0 by epsilon into garbage);
  * the tracer stamps exclusively from the injected clock: two identical
    virtual-clock load-harness runs produce BYTE-IDENTICAL Perfetto JSON
    and identical registry dumps;
  * every finished request's span set is complete (submit/queue/admit/
    first_token/finish, prefill_chunk events matching the metric delta,
    one token event per emitted token);
  * the threaded (real background loop) drive emits a schema-valid trace
    — same completeness per request, no ordering assumptions across
    requests;
  * the Prometheus exporter serves the text exposition over HTTP.
"""
import json
import os
import sys
import urllib.request

import pytest

from repro.obs import (TTFT_BUCKETS, MetricsRegistry, Tracer, dump_metrics,
                       dump_trace, perfetto_json, start_metrics_server)
from repro.obs.trace import request_events
from repro.serve.engine import EngineMetrics, EngineMetricsView

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)

from benchmarks.load_harness import (VirtualClock, build_engine,  # noqa: E402
                                     make_trace, run_threaded, run_virtual)


# --- registry ------------------------------------------------------------
def test_counter_gauge_histogram():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests", ("priority",))
    c.add(priority="0")
    c.add(2, priority="1")
    assert c.value(priority="1") == 2 and c.value(priority="0") == 1
    g = reg.gauge("depth", "queue depth")
    g.set(7)
    g.add(-2)
    assert g.value() == 5
    h = reg.histogram("lat_seconds", "latency", buckets=(0.01, 0.1, 1.0))
    for v in (0.005, 0.05, 0.5, 5.0):
        h.observe(v)
    assert h.count() == 4
    text = reg.prometheus_text()
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{priority="1"} 2' in text
    assert '# TYPE lat_seconds histogram' in text
    # cumulative le buckets: 1 <= 0.01, 2 <= 0.1, 3 <= 1.0, 4 <= +Inf
    assert 'lat_seconds_bucket{le="0.01"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 4' in text
    assert 'lat_seconds_count 4' in text


def test_registry_schema_enforced():
    reg = MetricsRegistry()
    c = reg.counter("a_total", "a", ("mode",))
    with pytest.raises(ValueError):
        c.add(wrong="x")
    with pytest.raises(ValueError):
        reg.gauge("a_total", "a", ("mode",))     # type mismatch
    with pytest.raises(ValueError):
        reg.counter("a_total", "a", ("other",))  # label-schema mismatch
    assert reg.counter("a_total", "a", ("mode",)) is c   # idempotent


def test_registry_dump_deterministic():
    def build():
        reg = MetricsRegistry()
        reg.counter("z_total", "z").add(3)
        h = reg.histogram("t_seconds", "t", ("k",), buckets=TTFT_BUCKETS)
        h.observe(0.004, k="a")
        h.observe(2.0, k="b")
        return reg

    a, b = build(), build()
    assert a.dump_json() == b.dump_json()
    assert a.prometheus_text() == b.prometheus_text()
    d = a.dump()
    assert d["t_seconds"]["kind"] == "histogram"
    assert d["t_seconds"]["series"]['k="a"']["count"] == 1


# --- EngineMetrics value type + view -------------------------------------
def test_summary_zero_tokens_is_zero():
    s = EngineMetrics().summary(max_batch=4)
    assert s["prefill_tok_s"] == 0.0
    assert s["decode_tok_s"] == 0.0
    assert s["occupancy"] == 0.0


def test_summary_nonzero_divides():
    m = EngineMetrics(prefill_s=2.0, prefill_tokens=10,
                      decode_s=0.5, decode_tokens=5, ticks=2,
                      occupancy_sum=4)
    s = m.summary(max_batch=2)
    assert s["prefill_tok_s"] == pytest.approx(5.0)
    assert s["decode_tok_s"] == pytest.approx(10.0)
    assert s["occupancy"] == pytest.approx(1.0)


def test_metrics_view_contract():
    view = EngineMetricsView(MetricsRegistry())
    assert view.ticks == 0
    view.ticks += 3                       # read-modify-write
    view.decode_tokens = 7
    view.prefill_s += 0.5
    assert view.ticks == 3 and view.decode_tokens == 7
    snap = view.snapshot()
    assert isinstance(snap, EngineMetrics) and snap.ticks == 3
    view.ticks += 1
    delta = view.since(snap)
    assert delta.ticks == 1 and delta.decode_tokens == 0
    view.decode_tokens = 0                # the bench's reset spelling
    view.decode_s = 0.0
    assert view.summary(4)["decode_tok_s"] == 0.0
    with pytest.raises(AttributeError):
        view.not_a_metric = 1
    with pytest.raises(AttributeError):
        _ = view.not_a_metric


# --- tracer --------------------------------------------------------------
def test_tracer_disabled_is_noop_and_ring_drops():
    clk = iter(float(i) for i in range(100)).__next__
    tr = Tracer(clock=clk, capacity=4, enabled=False)
    tr.event("submit", rid=1)
    assert tr.events() == [] and tr.dropped == 0
    tr.enabled = True
    for i in range(6):
        tr.event("token", rid=i)
    assert len(tr.events()) == 4 and tr.dropped == 2
    assert [e.rid for e in tr.events()] == [2, 3, 4, 5]   # oldest dropped
    tr.clear()
    assert tr.events() == [] and tr.dropped == 0


def test_tracer_span_and_perfetto_bytes():
    clk = iter([1.0, 1.5, 2.0, 3.0]).__next__
    tr = Tracer(clock=clk, enabled=True)
    with tr.span("decode", batch=2):
        pass
    tr.event("first_token", rid=7)
    evs = tr.events()
    assert evs[0].dur == pytest.approx(0.5) and evs[0].rid is None
    text = tr.perfetto()
    assert text == perfetto_json(evs)     # pure function of the events
    doc = json.loads(text)
    rows = doc["traceEvents"]
    meta = [r for r in rows if r["ph"] == "M"]
    assert {"engine", "requests"} <= {
        r["args"]["name"] for r in meta if r["name"] == "process_name"}
    span = next(r for r in rows if r.get("ph") == "X")
    assert span["dur"] == pytest.approx(0.5e6)            # microseconds
    inst = next(r for r in rows if r.get("ph") == "i")
    assert inst["tid"] == 7 and inst["pid"] == 1


# --- exporters -----------------------------------------------------------
def test_http_exporter_and_dumps(tmp_path):
    reg = MetricsRegistry()
    reg.counter("hits_total", "hits").add(5)
    server = start_metrics_server(reg, port=0)
    try:
        port = server.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=10).read().decode()
        assert "hits_total 5" in body
        js = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics.json", timeout=10).read()
        assert json.loads(js)["hits_total"]["series"][""] == 5
    finally:
        server.shutdown()
    mp = tmp_path / "m.prom"
    assert dump_metrics(reg, str(mp)) == mp.read_text()
    tr = Tracer(clock=iter([0.0]).__next__, enabled=True)
    tr.event("submit", rid=0)
    tp = tmp_path / "t.json"
    assert dump_trace(tr, str(tp)) == tp.read_text()
    json.loads(tp.read_text())


# --- engine integration --------------------------------------------------
def _virtual_run(trace_kw=None, **knobs):
    eng, cfg = build_engine("yi-9b", clock=VirtualClock(), trace=True,
                            **knobs)
    trace = make_trace(8, 100.0, cfg.vocab_size, seed=0,
                       deadline_budgets={0: 0.8, 1: 0.5},
                       **(trace_kw or {}))
    rep = run_virtual(eng, trace)
    assert rep["drained"], rep
    return eng


def test_virtual_runs_byte_identical():
    a = _virtual_run()
    b = _virtual_run()
    assert a.tracer.perfetto() == b.tracer.perfetto()
    assert a.registry.dump_json() == b.registry.dump_json()
    assert a.tracer.events()                       # not vacuous


def test_virtual_span_sets_complete():
    # prompts above the chunk size exercise the staged/chunked admission
    eng = _virtual_run(trace_kw={"prompt_lens": (4, 12, 20)},
                       prefill_chunk=8)
    evs = eng.tracer.events()
    per_req = request_events(evs)
    assert len(per_req) == 8
    for rid, res in per_req.items():
        names = [e.name for e in res]
        for need in ("submit", "queue", "admit", "first_token", "finish"):
            assert need in names, (rid, need, names)
        assert names.index("submit") < names.index("admit") \
            < names.index("first_token") < names.index("finish")
        assert names.count("submit") == names.count("finish") == 1
    # event/metric pairing: chunk events match the counter, token events
    # match tokens emitted (one first_token per request, rest tokens)
    m = eng.metrics
    assert sum(n == "prefill_chunk" for e in evs
               for n in [e.name]) == m.prefill_chunks > 0
    tok_ev = sum(e.name in ("first_token", "token") for e in evs)
    assert tok_ev == m.decode_tokens + len(per_req)
    # engine-phase lanes carry complete spans
    phases = {e.name for e in evs if e.rid is None}
    assert {"admit", "prefill", "decode", "emit"} <= phases
    # deterministic registry state reflects the run
    dump = eng.registry.dump()
    assert dump["engine_requests_submitted_total"]["series"]
    assert dump["engine_ttft_seconds"]["series"]
    assert dump["engine_info"]["series"]


def test_threaded_trace_schema_valid():
    eng, cfg = build_engine("yi-9b", trace=True)
    trace = make_trace(6, 200.0, cfg.vocab_size, seed=1,
                       deadline_budgets={0: None, 1: None})
    rep = run_threaded(eng, trace, time_scale=0.01)
    assert rep["finished"] == 6, rep
    doc = json.loads(eng.tracer.perfetto())        # parses
    assert doc["traceEvents"]
    per_req = request_events(eng.tracer.events())
    assert len(per_req) == 6
    for rid, res in per_req.items():
        names = [e.name for e in res]
        # unordered-tolerant across requests; per-request completeness
        # holds because every emission point runs under the engine lock
        assert names.count("submit") == names.count("finish") == 1, names
        assert "first_token" in names and "admit" in names
        ts = [e.ts for e in res]
        assert ts == sorted(ts), f"rid {rid}: events not time-ordered"


def test_tracing_off_records_nothing_but_metrics_live():
    eng, cfg = build_engine("yi-9b", clock=VirtualClock())
    trace = make_trace(4, 100.0, cfg.vocab_size, seed=3,
                       deadline_budgets={0: None, 1: None})
    run_virtual(eng, trace)
    assert eng.tracer.events() == [] and not eng.tracer.enabled
    assert eng.metrics.decode_tokens > 0
    assert eng.registry.dump()["engine_requests_finished_total"][
        "series"][""] == 4
    # gauges settle back to idle
    assert eng.registry.gauge("engine_queue_depth").value() == 0
    assert eng.registry.gauge("engine_active_slots").value() == 0
