"""Hardware cost model vs the paper's stated numbers (Tables I/II, Figs 15-18)."""
import pytest

from repro.core import cost_model as cm


def test_table1_conventional_lut():
    """Paper Table I: SRAMs and muxes for 3b..8b conventional LUT."""
    expected = {3: (48, 42), 4: (128, 120), 5: (320, 310),
                6: (768, 756), 7: (1792, 1778), 8: (4096, 4080)}
    for bits, (srams, muxes) in expected.items():
        c = cm.conventional_cost(bits)
        assert (c.srams, c.muxes) == (srams, muxes), bits


def test_fig2_dc_counts():
    """Paper Fig 2 totals: 24 SRAMs, 36 muxes, 3 HA, 3 FA for 4b D&C."""
    c = cm.dc_cost(4)
    assert (c.srams, c.muxes, c.has, c.fas) == (24, 36, 3, 3)


@pytest.mark.parametrize("bits,expected", [
    (4, (10, 36, 3, 3)),
    (8, (36, 120, 11, 21)),
    (16, (136, 432, 31, 105)),
])
def test_table2_optimized_dc(bits, expected):
    """Paper Table II: optimized D&C component counts for 4/8/16 b."""
    c = cm.opt_dc_cost(bits)
    assert (c.srams, c.muxes, c.has, c.fas) == expected


def test_fig9_approx_dc():
    """Paper Fig 9: ApproxD&C needs 10 SRAMs, 18 muxes, no adders."""
    c = cm.approx_dc_cost(4)
    assert (c.srams, c.muxes, c.has, c.fas) == (10, 18, 0, 0)


def test_fig10_approx_dc2():
    """Paper Fig 10: 12 SRAMs, 18 muxes, 4 HA, 1 FA."""
    c = cm.approx_dc2_cost(4)
    assert (c.srams, c.muxes, c.has, c.fas) == (12, 18, 4, 1)


def test_fig15_energy_share():
    """Paper: multiplier = 47.96 fJ = ~0.0276 % of 173.8 pJ/bit -> <0.1 %."""
    rep = cm.energy_report()
    assert rep["multiplier_share"] == pytest.approx(2.76e-4, rel=0.02)
    assert rep["multiplier_share"] < 1e-3              # abstract: <0.1 %


def test_fig16_area_ratio():
    """Paper abstract: optimized D&C ~3.7x less area than conventional."""
    rep = cm.area_report(4)
    ratio = rep["opt_dc"]["area_vs_conventional"]
    assert 3.3 <= ratio <= 4.1, ratio
    # approx variants are even smaller
    assert rep["approx_dc"]["area_vs_conventional"] > ratio


def test_fig18_array_overhead():
    """Paper: 4 LUNA units on the 8x8 array = 32 % area overhead."""
    rep = cm.array_overhead(4)
    assert rep["overhead_fraction"] == pytest.approx(0.32, abs=0.01)
    assert rep["unit_area_um2"] == 287.0
    assert rep["total_area_um2"] == 3650.0


def test_storage_scaling_beats_conventional():
    """The D&C scalability claim: storage linear vs exponential in bits."""
    for bits in (4, 8, 16):
        assert cm.opt_dc_cost(bits).srams < cm.conventional_cost(bits).srams
    # 16b: 2M -> 136 cells
    assert cm.conventional_cost(16).srams == 2097152
    assert cm.opt_dc_cost(16).srams == 136
