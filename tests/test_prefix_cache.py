"""Prefix-cache subsystem: radix-tree mechanics + warm-admission parity.

The load-bearing pins:
  * warm-prefix admission is TOKEN-IDENTICAL to cold prefill for the
    transformer (paged, with and without chunked prefill), mamba2 (dense
    state snapshots) and zamba2 (paged blocks + snapshot, split substrate);
  * shared pool blocks are never written in place (copy-on-write): their
    contents are bit-identical before and after a warm admission decodes;
  * eviction frees cache-held blocks under pool pressure and admission
    still completes correctly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.registry import get_config, get_model
from repro.serve.config import EngineConfig
from repro.serve.engine import Engine, Request

from repro.serve.paged import BlockAllocator
from repro.serve.prefix_cache import PrefixCache


def _engine(cfg, params, **knobs):
    """Engine built from knob kwargs (the legacy shim is gone: every
    construction goes through an explicit EngineConfig)."""
    return Engine(cfg, params, EngineConfig(**knobs))



def _setup(arch="yi-9b", **over):
    cfg = get_config(arch).reduced(dtype="float32", attn_impl="full", **over)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, params


def _shared_head_prompts(cfg, head_len=18, tails=(6, 5, 7), seed=0):
    """The shared-system-prompt shape: one head, divergent tails."""
    rng = np.random.default_rng(seed)
    head = rng.integers(1, cfg.vocab_size, head_len).tolist()
    return [head + rng.integers(1, cfg.vocab_size, n).tolist()
            for n in tails]


def _serve_each(eng, prompts, max_new=5):
    """One request at a time (isolates warm-hit behavior from batching)."""
    reqs = [Request(rid=i, prompt=p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        assert eng.serve([r])["done"]
    return [r.out for r in reqs]


# ---------------------------------------------------------------------------
# radix-tree mechanics (host-side, no model)
# ---------------------------------------------------------------------------

def test_radix_insert_match_split_blocks():
    a = BlockAllocator(20, 4)
    pc = PrefixCache(block_size=4, backend=a, max_nodes=32)
    p1 = list(range(1, 13))                   # 12 tokens = 3 whole blocks
    b1 = a.alloc(3)
    pc.insert(p1, blocks=b1)
    assert all(a.refcount(b) == 2 for b in b1)    # request + cache

    h = pc.match(p1, max_len=11)              # same prompt, tail reserved
    assert h.length == 8 and h.blocks == b1[:2]
    h = pc.match(p1 + [77], max_len=12)       # strict extension: all blocks
    assert h.length == 12 and h.blocks == b1

    # divergent tail: partial-edge hit still shares the head's whole blocks
    p2 = p1[:10] + [99, 98]
    h = pc.match(p2, max_len=11)
    assert h.length == 8 and h.blocks == b1[:2]

    # inserting the divergent prompt splits the edge; the new internal node
    # derives the shared head's block prefix (and co-owns it)
    b2 = a.alloc(3)
    pc.insert(p2, blocks=b2)
    assert a.refcount(b1[0]) == 3             # request + leaf + split node
    h = pc.match(p1[:10] + [55, 56], max_len=11)
    assert h.length == 8 and h.blocks == b1[:2]


def test_radix_state_snapshots_match_exact_boundary_only():
    pc = PrefixCache(max_nodes=8)             # recurrent-dense backend
    pc.insert([1, 2, 3], state="s3")
    pc.insert([1, 2, 3, 4, 5], state="s5")
    h = pc.match([1, 2, 3, 4, 5, 6], max_len=5, need_state=True)
    assert h.length == 5 and h.state == "s5"
    # the deeper snapshot is beyond max_len: fall back to the ancestor
    h = pc.match([1, 2, 3, 4, 5], max_len=4, need_state=True)
    assert h.length == 3 and h.state == "s3"
    # a state snapshot never serves a partial (mid-edge) match
    assert pc.match([1, 2, 9], max_len=2, need_state=True) is None
    assert pc.match([9, 9], max_len=1, need_state=True) is None


def test_lru_eviction_on_node_budget():
    pc = PrefixCache(max_nodes=2)
    pc.insert([1, 1], state="a")
    pc.insert([2, 2], state="b")
    assert pc.match([1, 1, 5], max_len=2, need_state=True).state == "a"
    pc.insert([3, 3], state="c")              # over budget: LRU leaf "b" goes
    assert pc.evictions == 1 and pc.node_count == 2
    assert pc.match([2, 2, 5], max_len=2, need_state=True) is None
    assert pc.match([1, 1, 5], max_len=2, need_state=True).state == "a"


def test_pool_shortage_evicts_only_unreferenced_nodes():
    a = BlockAllocator(6, 4)                  # 5 usable blocks
    pc = PrefixCache(block_size=4, backend=a, max_nodes=32)
    b1 = a.alloc(2)
    pc.insert([1] * 8, blocks=b1)
    a.release(b1)                             # request done: cache-only refs
    b2 = a.alloc(2)
    pc.insert([2] * 8, blocks=b2)             # this "request" stays live
    assert a.free_blocks == 1
    assert pc.evict_for(3) == 1               # only the unreferenced node
    assert a.free_blocks == 3
    assert pc.match([1] * 8 + [9], max_len=8) is None
    assert pc.match([2] * 8 + [9], max_len=8).blocks == b2
    # the live node's blocks never left the pool
    assert all(a.refcount(b) == 2 for b in b2)


# ---------------------------------------------------------------------------
# warm admission == cold prefill, per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [{}, {"prefill_chunk": 8}],
                         ids=["bucketed", "chunked"])
def test_warm_transformer_paged_matches_cold(kw):
    """Acceptance pin: the attention family under Engine(paged=True) —
    copy-on-write block sharing — is token-identical to cold prefill, with
    and without chunked prefill composing."""
    cfg, params = _setup()
    prompts = _shared_head_prompts(cfg)
    cold = _engine(cfg, params, max_batch=2, max_seq=48, paged=True,
                  block_size=8, **kw)
    ref = _serve_each(cold, prompts)
    warm = _engine(cfg, params, max_batch=2, max_seq=48, paged=True,
                  block_size=8, prefix_cache=True, **kw)
    outs = _serve_each(warm, prompts)
    assert outs == ref
    # prompts 2 and 3 share the 18-token head: 2 whole blocks reused each
    assert warm.metrics.prefix_hits == 2
    assert warm.metrics.prefix_tokens_reused == 32


@pytest.mark.parametrize("kw", [{}, {"prefill_chunk": 8}],
                         ids=["bucketed", "chunked"])
def test_warm_mamba2_matches_cold(kw):
    """Acceptance pin: the recurrent family reuses dense (conv, ssd) state
    snapshots captured from the state-continuing scan."""
    cfg, params = _setup("mamba2-1.3b")
    prompts = _shared_head_prompts(cfg)
    prompts.append(prompts[0] + [7, 8, 9])    # strict prefix extension
    cold = _engine(cfg, params, max_batch=2, max_seq=48, **kw)
    ref = _serve_each(cold, prompts, max_new=4)
    warm = _engine(cfg, params, max_batch=2, max_seq=48, prefix_cache=True,
                  **kw)
    outs = _serve_each(warm, prompts, max_new=4)
    assert outs == ref
    assert warm.metrics.prefix_hits >= 2
    assert warm.metrics.prefix_tokens_reused >= 32


@pytest.mark.parametrize("kw", [{}, {"prefill_chunk": 8}],
                         ids=["bucketed", "chunked"])
def test_warm_zamba2_paged_matches_cold(kw):
    """Acceptance pin: the hybrid's split substrate warms BOTH halves —
    shared attention blocks (COW) and the SSM state snapshot — at one
    block-aligned boundary."""
    cfg, params = _setup("zamba2-1.2b")
    prompts = _shared_head_prompts(cfg)
    cold = _engine(cfg, params, max_batch=2, max_seq=48, paged=True,
                  block_size=8, **kw)
    ref = _serve_each(cold, prompts, max_new=4)
    warm = _engine(cfg, params, max_batch=2, max_seq=48, paged=True,
                  block_size=8, prefix_cache=True, **kw)
    outs = _serve_each(warm, prompts, max_new=4)
    assert outs == ref
    assert warm.metrics.prefix_hits >= 1
    assert warm.metrics.prefix_tokens_reused >= 16


@pytest.mark.parametrize("kw", [{}, {"prefill_chunk": 8}],
                         ids=["bucketed", "chunked"])
def test_warm_two_prefix_families_sequential(kw):
    """Regression pin: cold A, warm A, cold B, warm B.  The warm-B gather
    reads pool blocks written AFTER the first warm admission compiled
    _seed_gather, so a gather that baked the pool in as a trace-time
    constant (instead of reading the traced ``caches`` argument) returns
    stale KV and diverges here."""
    cfg, params = _setup()
    rng = np.random.default_rng(11)
    head_a = rng.integers(1, cfg.vocab_size, 18).tolist()
    head_b = rng.integers(1, cfg.vocab_size, 18).tolist()
    prompts = [head_a + rng.integers(1, cfg.vocab_size, 6).tolist(),
               head_a + rng.integers(1, cfg.vocab_size, 5).tolist(),
               head_b + rng.integers(1, cfg.vocab_size, 6).tolist(),
               head_b + rng.integers(1, cfg.vocab_size, 5).tolist()]
    cold = _engine(cfg, params, max_batch=2, max_seq=48, paged=True,
                  block_size=8, **kw)
    ref = _serve_each(cold, prompts)
    warm = _engine(cfg, params, max_batch=2, max_seq=48, paged=True,
                  block_size=8, prefix_cache=True, **kw)
    outs = _serve_each(warm, prompts)
    assert outs == ref
    assert warm.metrics.prefix_hits == 2      # warm A and warm B
    assert warm.metrics.prefix_tokens_reused == 32


def test_shared_blocks_never_written_in_place():
    """COW pin: the pool content of every cache-shared block is
    bit-identical before and after a warm admission prefills + decodes."""
    cfg, params = _setup()
    prompts = _shared_head_prompts(cfg, tails=(6, 5))
    eng = _engine(cfg, params, max_batch=2, max_seq=48, paged=True,
                 block_size=8, prefix_cache=True)
    _serve_each(eng, prompts[:1])
    hit = eng.prefix_cache.match(prompts[1], max_len=len(prompts[1]) - 1)
    assert hit is not None and len(hit.blocks) == 2
    ids = jnp.asarray(hit.blocks)

    def pool_snapshot():
        return [np.asarray(jnp.take(leaf, ids, axis=ax))
                for leaf, ax, is_pool in zip(
                    jax.tree.leaves(eng.caches),
                    jax.tree.leaves(eng.backend._batch_axes),
                    jax.tree.leaves(eng.backend._pool_leaves)) if is_pool]

    before = pool_snapshot()
    _serve_each(eng, prompts[1:])             # warm admission + decode
    assert eng.metrics.prefix_hits == 1
    for a, b in zip(before, pool_snapshot()):
        np.testing.assert_array_equal(a, b)


def test_eviction_under_pool_pressure_keeps_serving():
    """A pool too small to hold every cached prefix evicts LRU nodes at
    admission and the workload still completes token-identically."""
    cfg, params = _setup()
    rng = np.random.default_rng(7)
    prompts = [rng.integers(1, cfg.vocab_size, 24).tolist()
               for _ in range(3)]             # disjoint: each caches 3 blocks
    cold = _engine(cfg, params, max_batch=1, max_seq=48, paged=True,
                  block_size=8, num_blocks=8)
    ref = _serve_each(cold, prompts, max_new=4)
    warm = _engine(cfg, params, max_batch=1, max_seq=48, paged=True,
                  block_size=8, num_blocks=8, prefix_cache=True)
    outs = _serve_each(warm, prompts, max_new=4)
    assert outs == ref
    assert warm.metrics.cache_evictions >= 1
    # the cache's surviving refs are exactly the outstanding pool blocks,
    # and a full sweep returns every one of them
    assert warm.allocator.used_blocks > 0
    warm.prefix_cache.evict_for(warm.backend.num_blocks)
    assert warm.allocator.used_blocks == 0


def test_prefix_cache_construction_contract():
    cfg, params = _setup()
    with pytest.raises(ValueError, match="prefix_cache"):
        _engine(cfg, params, max_batch=1, max_seq=32, prefix_cache=True)
    cfg_h, params_h = _setup("zamba2-1.2b")
    with pytest.raises(ValueError, match="prefix_cache"):
        _engine(cfg_h, params_h, max_batch=1, max_seq=32, prefix_cache=True)
    cfg_s, params_s = _setup("mamba2-1.3b")
    _engine(cfg_s, params_s, max_batch=1, max_seq=32, prefix_cache=True)


def test_warm_metrics_accounting():
    """prefill_tokens counts only re-prefilled tokens; the reused head is
    accounted separately (their sum is the full prompt)."""
    cfg, params = _setup("mamba2-1.3b")
    p1 = _shared_head_prompts(cfg, tails=(6,))[0]
    eng = _engine(cfg, params, max_batch=1, max_seq=48, prefix_cache=True)
    _serve_each(eng, [p1], max_new=3)
    base = eng.metrics.prefill_tokens
    r = Request(rid=9, prompt=p1 + [3, 1, 4], max_new=3)
    assert eng.serve([r])["done"]
    reused = eng.metrics.prefix_tokens_reused
    assert reused == len(p1)                  # whole first prompt reused
    assert eng.metrics.prefill_tokens - base == len(r.prompt) - reused


@pytest.mark.slow
@pytest.mark.parametrize("arch,kw", [
    ("yi-9b", {"paged": True, "block_size": 8}),
    ("yi-9b", {"paged": True, "block_size": 8, "prefill_chunk": 8}),
    ("mamba2-1.3b", {}),
    ("mamba2-1.3b", {"prefill_chunk": 8}),
    ("zamba2-1.2b", {"paged": True, "block_size": 8}),
    ("zamba2-1.2b", {"paged": True, "block_size": 8, "prefill_chunk": 8}),
])
def test_warm_concurrent_workload_parity_slow(arch, kw):
    """Nightly tier: a 6-request shared-head workload served CONCURRENTLY
    (slot contention, warm admissions interleaved with decode ticks) is
    token-identical with and without the prefix cache."""
    cfg, params = _setup(arch)
    prompts = _shared_head_prompts(cfg, head_len=24, tails=(6, 5, 7, 9, 4, 8))
    outs = {}
    for warm in (False, True):
        eng = _engine(cfg, params, max_batch=3, max_seq=64,
                     prefix_cache=warm, **kw)
        reqs = [Request(rid=i, prompt=p, max_new=5)
                for i, p in enumerate(prompts)]
        assert eng.serve(reqs)["done"]
        outs[warm] = [r.out for r in reqs]
    assert outs[True] == outs[False]
